"""Multi-device semantics (8 fake host devices via subprocess, so the main
pytest process keeps its single-device view): shard_map analyzer ≡ serial,
MoE EP ≡ local, sharded train step ≡ unsharded, cache specs legal."""

import os
import subprocess
import sys
import textwrap

# The jax>=0.6 API drift (AxisType / set_mesh / make_mesh kwargs) that
# used to quarantine this whole module is absorbed by repro.compat
# (make_mesh / set_mesh / shard_map); the snippets below run on every
# supported jax and a regression in the distributed path fails loudly.

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, timeout=560):
    full = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            "import sys\n"
            f"sys.path.insert(0, {SRC!r})\n" + textwrap.dedent(code))
    out = subprocess.run([sys.executable, "-c", full],
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0 and "OK" in out.stdout, \
        (out.stdout[-1000:], out.stderr[-3000:])


def test_distributed_binstats_equals_serial():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import make_mesh, set_mesh
    from jax.sharding import Mesh
    from repro.core.distributed import (binstats_local,
                                        distributed_binstats)
    rng = np.random.default_rng(0)
    n, n_bins, total = 4096, 64, 1e9
    ts = jnp.asarray(rng.uniform(0, total, n), jnp.float32)
    vals = jnp.asarray(rng.normal(10, 3, n), jnp.float32)
    mesh = make_mesh((8,), ('data',))
    dist = distributed_binstats(ts, vals, total, n_bins, mesh)
    inv = np.float32(n_bins / total)
    bins = jnp.clip((ts * inv).astype(jnp.int32), 0, n_bins - 1)
    ser = binstats_local(bins, vals, n_bins)
    np.testing.assert_allclose(np.asarray(dist)[:, :3],
                               np.asarray(ser)[:, :3], rtol=1e-4,
                               atol=1e-2)
    np.testing.assert_allclose(np.asarray(dist)[:, 3:],
                               np.asarray(ser)[:, 3:], rtol=1e-5)
    print('OK')
    """)


def test_moe_ep_and_replicated_equal_local():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import make_mesh, set_mesh
    from repro.models.moe import MoEConfig, moe_init, moe_forward
    from repro.models.shardrules import make_ctx
    cfg = MoEConfig(d_model=32, d_ff=16, n_experts=8, top_k=2,
                    n_shared=1, capacity_factor=2.0)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 16, 32)),
                    jnp.float32)
    out_l, _ = moe_forward(params, x, cfg, None)
    mesh = make_mesh((2, 4), ('data', 'model'))
    ctx = make_ctx(mesh)
    with set_mesh(mesh):
        out_ep, _ = moe_forward(params, x, cfg, ctx)
        out_rep, _ = moe_forward(params, x[:, :1], cfg, ctx)
    out_lr, _ = moe_forward(params, x[:, :1], cfg, None)
    np.testing.assert_allclose(np.asarray(out_l), np.asarray(out_ep),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out_lr), np.asarray(out_rep),
                               rtol=1e-4, atol=1e-4)
    print('OK')
    """)


def test_sharded_train_step_matches_single_device():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import make_mesh, set_mesh
    from repro.configs import get_smoke_config
    from repro.data.pipeline import DataConfig, make_batch
    from repro.train.step import (TrainConfig, init_state,
                                  make_train_step, state_specs,
                                  batch_specs, to_named)
    cfg = get_smoke_config('granite-moe-1b-a400m')
    tcfg = TrainConfig()
    state = init_state(cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in make_batch(
        cfg, DataConfig(batch=8, seq=16), 0).items()}
    # single device reference
    s_ref, m_ref = make_train_step(cfg, tcfg, None)(
        jax.tree.map(lambda x: x, state), batch)
    # 2x4 mesh
    mesh = make_mesh((2, 4), ('data', 'model'))
    sspec = to_named(state_specs(state, mesh), mesh)
    bspec = to_named(batch_specs(batch, mesh), mesh)
    step = jax.jit(make_train_step(cfg, tcfg, mesh),
                   in_shardings=(sspec, bspec), out_shardings=(sspec, None))
    with set_mesh(mesh):
        s_sh, m_sh = step(state, batch)
    np.testing.assert_allclose(float(m_ref['loss']), float(m_sh['loss']),
                               rtol=2e-3)
    a = np.asarray(s_ref['params']['final_norm']['scale'])
    b = np.asarray(s_sh['params']['final_norm']['scale'])
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)
    print('OK')
    """)


def test_serve_cache_specs_are_legal_shardings():
    _run("""
    import jax, jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.configs import get_smoke_config
    from repro.models.model import init_cache
    from repro.serve.engine import cache_specs
    from jax.sharding import NamedSharding
    mesh = make_mesh((2, 4), ('data', 'model'))
    for arch in ('hymba-1.5b', 'deepseek-v2-236b', 'mamba2-370m',
                 'h2o-danube-1.8b'):
        cfg = get_smoke_config(arch)
        caches = jax.eval_shape(lambda c=cfg: init_cache(c, 8, 64))
        specs = cache_specs(cfg, caches, mesh)
        jax.tree.map(lambda x, s: NamedSharding(mesh, s), caches, specs)
    print('OK')
    """)


def test_multipod_mesh_axes():
    _run("""
    from repro.compat import make_mesh
    from repro.models.shardrules import batch_axes, spec_for
    mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
    assert batch_axes(mesh) == ('pod', 'data')
    s = spec_for('segments/0/ffn/w_up', (4, 64, 128), mesh)
    assert s[1] == ('pod', 'data') and s[2] in ('model', ('model',)), s
    # non-divisible head dim falls back to replication
    s2 = spec_for('segments/0/attn/wq', (4, 64, 25, 8), mesh)
    assert s2[2] is None, s2
    print('OK')
    """)


def test_elastic_checkpoint_reshard_across_meshes(tmp_path):
    """Fault-tolerance: a checkpoint written from an 8-device (2,4) mesh
    restores onto a 4-device (2,2) mesh (elastic downscale) and the train
    step keeps producing the same loss."""
    _run("""
    import tempfile, jax, jax.numpy as jnp, numpy as np
    from repro.compat import make_mesh, set_mesh
    from repro.configs import get_smoke_config
    from repro.data.pipeline import DataConfig, make_batch
    from repro.models.shardrules import tree_shardings
    from repro.train import CheckpointManager
    from repro.train.step import (TrainConfig, init_state,
                                  make_train_step, state_specs,
                                  batch_specs, to_named)
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_smoke_config('granite-moe-1b-a400m')
    tcfg = TrainConfig()
    batch = {k: jnp.asarray(v) for k, v in make_batch(
        cfg, DataConfig(batch=8, seq=16), 0).items()}
    d = tempfile.mkdtemp()

    def mesh_of(shape):
        return make_mesh(shape, ('data', 'model'))

    # train 2 steps on the 8-device mesh, checkpoint
    mesh8 = mesh_of((2, 4))
    state = init_state(cfg, jax.random.PRNGKey(0))
    sspec8 = to_named(state_specs(state, mesh8), mesh8)
    step8 = jax.jit(make_train_step(cfg, tcfg, mesh8),
                    in_shardings=(sspec8, to_named(
                        batch_specs(batch, mesh8), mesh8)),
                    out_shardings=(sspec8, None))
    with set_mesh(mesh8):
        state, _ = step8(state, batch)
        state, m8 = step8(state, batch)
    mgr = CheckpointManager(d)
    mgr.save(state, 2)

    # restore onto a 4-device mesh (different sharding layout)
    mesh4 = mesh_of((2, 2))
    template = jax.eval_shape(
        lambda: init_state(cfg, jax.random.PRNGKey(0)))
    sh4 = {'step': NamedSharding(mesh4, P()),
           'params': tree_shardings(template['params'], mesh4),
           'opt': {'m': tree_shardings(template['opt']['m'], mesh4),
                   'v': tree_shardings(template['opt']['v'], mesh4)}}
    restored = mgr.restore(template, shardings=sh4)
    assert int(restored['step']) == 2
    sspec4 = to_named(state_specs(restored, mesh4), mesh4)
    step4 = jax.jit(make_train_step(cfg, tcfg, mesh4),
                    in_shardings=(sspec4, to_named(
                        batch_specs(batch, mesh4), mesh4)),
                    out_shardings=(sspec4, None))
    with set_mesh(mesh4):
        _, m4 = step4(restored, batch)
    # the 3rd-step loss on the downscaled mesh matches the 8-device run
    with set_mesh(mesh8):
        _, m8b = step8(state, batch)
    np.testing.assert_allclose(float(m4['loss']), float(m8b['loss']),
                               rtol=2e-3)
    print('OK')
    """)
