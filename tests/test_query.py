"""Declarative Query API tests: canonical-hash stability across
processes, order-insensitive cache keys (a reordered re-query is a PURE
summary hit — zero shard reads), back-compat shims (old-style kwargs and
Query-style calls bit-identical and sharing cache entries), predicate
pushdown vs a scan-then-mask oracle (time windows straddling shard
boundaries, empty-result predicates), fused N-query batches bit-identical
to N sequential single-query runs on all three backends (append/delta
runs included), and pre-Query-era cache entries missing gracefully and
being swept by the manifest-write GC."""

import dataclasses
import os
import shutil
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (PipelineConfig, Query, SyntheticSpec, TraceStore,
                        VariabilityPipeline, append_rank_db,
                        generate_synthetic, run_aggregation, run_append,
                        run_generation, run_queries, trace_remainder,
                        truncate_trace, write_rank_db)
from repro.core.query import QueryPlan, SUMMARY_VERSION
from repro.core.sharding import ShardPlan
from repro.core.tracestore import partial_filename, summary_filename

_NS = 1_000_000_000
STAT_FIELDS = ("count", "sum", "sumsq", "min", "max")


@pytest.fixture(scope="module")
def base(tmp_path_factory):
    """One generated store (and its source DBs + the full trace for the
    append tests); each test works on a cheap directory copy."""
    spec = SyntheticSpec(n_ranks=2, kernels_per_rank=4000,
                        memcpys_per_rank=600, duration_s=40.0,
                        n_anomaly_windows=2, seed=11)
    ds = generate_synthetic(spec)
    t0 = int(ds.traces[0].kernels.start.min())
    cutoff = (t0 // _NS) * _NS + 30 * _NS
    root = tmp_path_factory.mktemp("query_base")
    paths = []
    for tr in ds.traces:
        p = str(root / f"rank{tr.rank}.sqlite")
        write_rank_db(p, truncate_trace(tr, cutoff))
        paths.append(p)
    store_dir = str(root / "store")
    run_generation(paths, store_dir, n_ranks=2)
    return ds, paths, cutoff, store_dir


@pytest.fixture
def store(base, tmp_path):
    _, _, _, store_dir = base
    dst = str(tmp_path / "s")
    shutil.copytree(store_dir, dst)
    return TraceStore(dst)


def _assert_results_equal(a, b, perm=None):
    """Bit-identity between two AggregationResults; ``perm`` maps b's
    metric axis onto a's (for reordered-metrics comparisons)."""
    idx = np.arange(len(a.metrics)) if perm is None else np.asarray(perm)
    for f in STAT_FIELDS:
        np.testing.assert_array_equal(getattr(a.grouped, f),
                                      getattr(b.grouped, f)[..., idx])
    np.testing.assert_array_equal(a.group_keys, b.group_keys)
    if "quantile" in a.reduced:
        np.testing.assert_array_equal(
            a.reduced["quantile"].counts,
            b.reduced["quantile"].counts[..., idx, :])
    assert set(a.copy_kind_bytes) == set(b.copy_kind_bytes)
    for k in a.copy_kind_bytes:
        np.testing.assert_array_equal(a.copy_kind_bytes[k],
                                      b.copy_kind_bytes[k])


# --- canonical form ---------------------------------------------------------

def test_canonical_form_is_order_insensitive():
    a = Query(metrics=("m_bytes", "k_stall"), group_by="m_kind",
              reducers=("quantile", "moments"), ranks=(1, 0, 1),
              transfer_kinds=(8, 1))
    b = Query(metrics=("k_stall", "m_bytes"), group_by="m_kind",
              reducers=("moments", "quantile"), ranks=(0, 1),
              transfer_kinds=(1, 8))
    assert a.canonical() == b.canonical()
    assert a.cache_key() == b.cache_key()
    # different predicates / metrics do change the key
    assert a.cache_key() != Query(metrics=("k_stall",)).cache_key()
    assert a.cache_key() != dataclasses.replace(
        a, transfer_kinds=(1,)).cache_key()


def test_quantile_score_folds_reducer_into_canonical_suite():
    a = Query(metrics=("k_stall",), anomaly_score="p99")
    b = Query(metrics=("k_stall",), reducers=("moments", "quantile"))
    assert a.canonical_reducers == ("moments", "quantile")
    assert a.cache_key() == b.cache_key()
    # the score itself is NOT part of the identity
    assert a.cache_key() == dataclasses.replace(
        a, anomaly_score="iqr").cache_key()


def test_query_json_roundtrip():
    q = Query(metrics=("k_stall", "m_bytes"), group_by="m_kind",
              time_window=(100, 200), ranks=(0,), anomaly_score="p95",
              interval_ns=1000)
    assert Query.from_json(q.to_json()) == q
    with pytest.raises(ValueError):
        Query.from_spec({"metrics": ["k_stall"], "bogus_field": 1})
    with pytest.raises(ValueError):
        Query(metrics=("k_stall",), time_window=(200, 100))
    with pytest.raises(ValueError):
        Query(metrics=())


def test_cache_key_stable_across_processes():
    """The canonical hash is the on-disk cache identity — it must not
    depend on PYTHONHASHSEED or any per-process state."""
    q = Query(metrics=("m_bytes", "k_stall"), group_by="m_kind",
              transfer_kinds=(2, 1), time_window=(10, 20))
    code = ("from repro.core import Query; "
            "print(Query(metrics=('m_bytes', 'k_stall'), "
            "group_by='m_kind', transfer_kinds=(2, 1), "
            "time_window=(10, 20)).cache_key())")
    keys = []
    for seed in ("0", "1"):
        env = dict(os.environ, PYTHONHASHSEED=seed,
                   PYTHONPATH="src" + os.pathsep
                   + os.environ.get("PYTHONPATH", ""))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, check=True,
                             cwd=os.path.dirname(os.path.dirname(
                                 os.path.abspath(__file__))))
        keys.append(out.stdout.strip())
    assert keys[0] == keys[1] == q.cache_key()


# --- order-insensitive cache (satellite: reorder = pure hit) ---------------

def test_reordered_requery_is_pure_cache_hit(store):
    r1 = run_aggregation(store, metrics=["m_duration", "k_stall"],
                         group_by="m_kind",
                         reducers=("moments", "quantile"))
    assert not r1.from_cache
    assert len(store.summary_keys()) == 1
    fresh = TraceStore(store.root)
    r2 = run_aggregation(fresh, metrics=["k_stall", "m_duration"],
                         group_by="m_kind",
                         reducers=("quantile", "moments"))
    assert r2.from_cache
    assert fresh.io_counts["shard_reads"] == 0
    assert fresh.io_counts["partial_reads"] == 0
    assert len(fresh.summary_keys()) == 1       # no second entry minted
    assert r2.metrics == ["k_stall", "m_duration"]
    # same answer, axis permuted back to the caller's order
    _assert_results_equal(r1, r2, perm=[1, 0])


def test_interval_spelling_of_store_layout_is_pure_cache_hit(store):
    """``interval_ns=<generation interval>`` re-derives the manifest plan
    — the planner must mint the manifest-plan key so both spellings
    share ONE summary entry (structurally, not by numeric coincidence)."""
    gen_interval = int(store.read_manifest().extra["interval_ns"])
    r1 = run_aggregation(store, metrics=["k_stall"], group_by="m_kind")
    assert not r1.from_cache
    assert len(store.summary_keys()) == 1
    fresh = TraceStore(store.root)
    r2 = run_aggregation(fresh, metrics=["k_stall"], group_by="m_kind",
                         interval_ns=gen_interval)
    assert r2.from_cache
    assert fresh.io_counts["shard_reads"] == 0
    assert fresh.io_counts["partial_reads"] == 0
    assert len(fresh.summary_keys()) == 1       # no second entry minted
    _assert_results_equal(r1, r2)
    # the coinciding spelling resolves to the manifest plan OBJECT
    qplan = QueryPlan.compile(TraceStore(store.root),
                              [Query(metrics=("k_stall",),
                                     interval_ns=gen_interval)])
    assert qplan.lanes[0].plan is qplan.file_plan
    # a genuinely different granularity still gets its own entry
    r3 = run_aggregation(TraceStore(store.root), metrics=["k_stall"],
                         group_by="m_kind", interval_ns=2 * gen_interval)
    assert not r3.from_cache
    assert len(TraceStore(store.root).summary_keys()) == 2


def test_old_style_and_query_style_share_cache_and_results(store):
    old = run_aggregation(store, metrics=["k_stall", "m_bytes"],
                          group_by="m_kind")
    fresh = TraceStore(store.root)
    qr = run_queries(fresh, [Query(metrics=("k_stall", "m_bytes"),
                                   group_by="m_kind")])[0]
    assert qr.cache_hit
    assert fresh.io_counts["shard_reads"] == 0
    _assert_results_equal(old, qr.result)
    # and the other way: a Query-made entry serves an old-style call
    q2 = Query(metrics=("m_duration",), group_by="k_device")
    run_queries(store, [q2])
    fresh2 = TraceStore(store.root)
    r2 = run_aggregation(fresh2, metrics=["m_duration"],
                         group_by="k_device")
    assert r2.from_cache and fresh2.io_counts["shard_reads"] == 0


def test_pipeline_config_to_query_shares_engine_and_cache(store):
    cfg = PipelineConfig(backend="serial", metrics=["k_stall"],
                         group_by="m_kind", anomaly_score="p99")
    pipe = VariabilityPipeline(cfg)
    agg = pipe.aggregate(store.root)
    assert "quantile" in agg.reduced        # score pulled the sketch in
    out = pipe.query(store.root, [cfg.to_query()])
    assert out[0].cache_hit
    _assert_results_equal(agg, out[0].result)
    assert out[0].anomalies is not None     # fenced on the query's score


# --- predicate pushdown vs scan-then-mask oracle ---------------------------

def _masked_store(store, query, out_dir):
    """The oracle: a store holding ONLY the mask-passing rows (written in
    the same shard layout), to be aggregated with the predicate-free rest
    of the query. Pushdown is correct iff the filtered engine run equals
    this unfiltered run bit for bit."""
    dst = TraceStore(out_dir)
    for idx in store.shard_indices():
        cols = store.read_shard(idx)
        mask = query.row_mask(cols)
        if mask is not None:
            cols = {c: np.asarray(v)[mask] for c, v in cols.items()}
        dst.write_shard(idx, cols)
    dst.write_manifest(store.read_manifest())
    return dst


def _strip_predicates(q):
    return dataclasses.replace(q, time_window=None, ranks=None,
                               kernel_names=None, transfer_kinds=None)


@pytest.mark.parametrize("case", ["straddle", "kinds_ranks", "names",
                                  "empty", "combined"])
def test_pushdown_matches_scan_then_mask_oracle(store, tmp_path, case):
    man = store.read_manifest()
    plan = ShardPlan(man.t_start, man.t_end, man.n_shards)
    edges = plan.boundaries()
    # a window straddling shard boundaries mid-shard on both ends
    straddle = (int(edges[2] + (edges[3] - edges[2]) // 3),
                int(edges[7] + (edges[8] - edges[7]) // 2))
    kernel_names = None
    for idx in store.shard_indices():
        cols = TraceStore(store.root).read_shard(idx)
        if len(cols["k_name"]):
            kernel_names = tuple(np.unique(cols["k_name"])[:2].astype(int))
            break
    q = {
        "straddle": Query(metrics=("k_stall", "m_duration"),
                          group_by="m_kind", time_window=straddle),
        "kinds_ranks": Query(metrics=("m_bytes",), group_by="m_kind",
                             transfer_kinds=(1, 2), ranks=(0,)),
        "names": Query(metrics=("k_stall",), group_by="k_device",
                       kernel_names=kernel_names),
        "empty": Query(metrics=("k_stall",), group_by="m_kind",
                       transfer_kinds=(9999,)),
        "combined": Query(metrics=("k_stall", "m_bytes"),
                          reducers=("moments", "quantile"),
                          time_window=straddle, ranks=(1,),
                          transfer_kinds=(1, 8)),
    }[case]
    got = run_queries(store, [q])[0]
    oracle_store = _masked_store(TraceStore(store.root), q,
                                 str(tmp_path / "oracle"))
    want = run_queries(oracle_store, [_strip_predicates(q)])[0]
    _assert_results_equal(want.result, got.result)
    if case == "empty":
        assert got.result.stats.count.sum() == 0
        assert got.rows_filtered == got.rows_scanned > 0


def test_time_window_prunes_shard_reads(store):
    man = store.read_manifest()
    plan = ShardPlan(man.t_start, man.t_end, man.n_shards)
    edges = plan.boundaries()
    q = Query(metrics=("k_stall",),
              time_window=(int(edges[3]), int(edges[6])))
    qplan = QueryPlan.compile(store, [q])
    assert qplan.lanes[0].pruned == [3, 4, 5]
    assert qplan.lanes[0].shards_pruned == man.n_shards - 3
    fresh = TraceStore(store.root)
    qr = run_queries(fresh, [q])[0]
    assert fresh.io_counts["shard_reads"] == 3
    assert qr.shards_pruned == man.n_shards - 3
    assert qr.recomputed_shards == 3
    # a window entirely below the plan start still scans file 0 (clipped
    # rows live there), never crashes, and returns the empty answer
    q_below = Query(metrics=("k_stall",),
                    time_window=(man.t_start - 10 * _NS,
                                 man.t_start - 5 * _NS))
    qp2 = QueryPlan.compile(store, [q_below])
    assert qp2.lanes[0].pruned == [0]
    assert run_queries(TraceStore(store.root),
                       [q_below])[0].result.stats.count.sum() == 0


# --- fusion: batch == sequential, on all three backends --------------------

def _mixed_queries(man):
    plan = ShardPlan(man.t_start, man.t_end, man.n_shards)
    edges = plan.boundaries()
    return [
        Query(metrics=("k_stall",), group_by="m_kind"),
        Query(metrics=("m_duration", "m_bytes"), group_by="m_kind",
              transfer_kinds=(1, 2)),
        Query(metrics=("k_stall", "m_duration"),
              reducers=("moments", "quantile"), ranks=(0,)),
        Query(metrics=("m_bytes",),
              time_window=(int(edges[1]), int(edges[5]))),
    ]


def _fused_vs_sequential(store_dir, backend, tmp_path):
    man = TraceStore(store_dir).read_manifest()
    queries = _mixed_queries(man)
    cfg = PipelineConfig(backend=backend, n_ranks=2)
    pipe = VariabilityPipeline(cfg)

    fused_dir = str(tmp_path / f"fused_{backend}")
    shutil.copytree(store_dir, fused_dir)
    fused = pipe.query(fused_dir, queries)
    assert not any(qr.cache_hit for qr in fused)

    for q, qf in zip(queries, fused):
        solo_dir = str(tmp_path / f"solo_{backend}_{q.cache_key()}")
        shutil.copytree(store_dir, solo_dir)
        solo = pipe.query(solo_dir, [q])[0]
        assert not solo.cache_hit
        _assert_results_equal(solo.result, qf.result)
        np.testing.assert_array_equal(solo.anomalies.scores,
                                      qf.anomalies.scores)


def test_fused_batch_equals_sequential_serial(base, tmp_path):
    _fused_vs_sequential(base[3], "serial", tmp_path)


def test_fused_batch_equals_sequential_process(base, tmp_path):
    _fused_vs_sequential(base[3], "process", tmp_path)


def test_fused_batch_equals_sequential_jax(base, tmp_path):
    pytest.importorskip("jax")
    _fused_vs_sequential(base[3], "jax", tmp_path)


@pytest.mark.parametrize("backend", ["serial", "jax"])
def test_fused_delta_after_append_bit_identical_to_cold(base, tmp_path,
                                                        backend):
    """The acceptance bar's delta leg: warm a fused batch, append new
    trace, re-run the batch as a DELTA (clean shards from each lane's
    partial cache), and compare every query against a cold standalone
    run over the appended store — bit-identical, with fewer shard reads
    than shards."""
    if backend == "jax":
        pytest.importorskip("jax")
    ds, base_paths, cutoff, _ = base
    work = tmp_path / "delta"
    os.makedirs(work)
    paths = []
    for tr in ds.traces:
        p = str(work / f"rank{tr.rank}.sqlite")
        write_rank_db(p, truncate_trace(tr, cutoff))
        paths.append(p)
    store_dir = str(work / "s")
    run_generation(paths, store_dir, n_ranks=2)
    man = TraceStore(store_dir).read_manifest()
    queries = _mixed_queries(man)

    run_queries(store_dir, queries, backend=backend)   # warm partials
    for tr, p in zip(ds.traces, paths):
        append_rank_db(p, trace_remainder(tr, cutoff))
    rep = run_append(paths, store_dir)
    assert rep.n_new_shards > 0

    fresh = TraceStore(store_dir)
    delta = run_queries(fresh, queries, backend=backend)
    n_files = fresh.read_manifest().n_shards
    assert fresh.io_counts["shard_reads"] < n_files
    assert all(not qr.cache_hit and qr.partial_hits > 0 for qr in delta)

    for q, qd in zip(queries, delta):
        cold_dir = str(work / f"cold_{q.cache_key()}")
        shutil.copytree(store_dir, cold_dir)
        cs = TraceStore(cold_dir)
        cs.clear_summaries()
        cs.clear_partials()
        cold = run_queries(cs, [q], backend=backend)[0]
        _assert_results_equal(cold.result, qd.result)


def test_batch_dedupes_canonically_equal_lanes(store):
    """Two queries in ONE batch whose canonical forms coincide (reordered
    metrics/reducers, re-ordered predicate subsets) share a single
    computation — and both answers come back in their own metric order."""
    a = Query(metrics=("k_stall", "m_duration"), group_by="m_kind",
              transfer_kinds=(1, 2))
    b = Query(metrics=("m_duration", "k_stall"), group_by="m_kind",
              transfer_kinds=(2, 1))
    out = run_queries(store, [a, b])
    n_files = store.read_manifest().n_shards
    assert store.io_counts["shard_reads"] == n_files   # one scan, not two
    _assert_results_equal(out[0].result, out[1].result, perm=[1, 0])
    assert out[0].result.metrics == ["k_stall", "m_duration"]
    assert out[1].result.metrics == ["m_duration", "k_stall"]


# --- stale-cache migration -------------------------------------------------

def test_pre_query_scheme_entries_miss_and_are_gcd(store):
    """Entries written under the pre-Query key scheme (SUMMARY_VERSION 3)
    must never be served — including a version-3 payload planted AT the
    current key — and the manifest-write GC must sweep them."""
    # plant: an old-scheme summary under a foreign key, an old-version
    # payload at the CURRENT key, and an old-scheme partial file
    q = Query(metrics=("k_stall",), group_by="m_kind")
    man = store.read_manifest()
    plan_key = (man.t_start, man.t_end, man.n_shards)
    cur_key = store.summary_key(plan_key, query=q)
    old_payload = {"version": np.asarray(3, np.int64),
                   "covered": np.zeros((0, 3), np.int64)}
    store.write_summary(cur_key, old_payload)
    store.write_summary("00ddba11deadbeef", old_payload)
    store.write_partial(0, "00ddba11deadbeef", {
        "version": np.asarray(3, np.int64),
        "fingerprint": np.asarray([0, 1, 2], np.int64)})

    res = run_aggregation(store, query=q)
    assert not res.from_cache                    # graceful miss, no crash
    assert len(res.recomputed_shards) == man.n_shards

    store.write_manifest(man)                    # triggers gc_stale
    assert "00ddba11deadbeef" not in store.summary_keys()
    assert not store.has_partial(0, "00ddba11deadbeef")
    assert partial_filename(0, "00ddba11deadbeef") \
        not in store.partial_names(0)
    # the recompute's own (version-4) entries survived the sweep
    assert os.path.exists(os.path.join(store.root,
                                       summary_filename(cur_key)))
    again = run_aggregation(TraceStore(store.root), query=q)
    assert again.from_cache


def test_summary_version_is_bumped_for_query_scheme():
    # the migration story above rests on this — pre-Query stores carried 3
    assert SUMMARY_VERSION >= 4
