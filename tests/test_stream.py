"""Streaming ingest plane + v1 API tests: live-writer tailing with no
duplicated and no lost rows, crash-interrupted ingest ticks rolling
FORWARD from the intent journal (never double-ingesting), fence-event
push over the v1 long-poll endpoint, full v1 route coverage with the
shared error envelope, legacy aliases answering with a ``Deprecation``
header, and the legacy metric-arg spellings warning while minting
bit-identical cache keys."""

import os
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import (PipelineConfig, Query, SyntheticSpec, TraceStore,
                        VariabilityPipeline, append_rank_db,
                        generate_synthetic, run_aggregation,
                        run_generation, trace_remainder, truncate_trace,
                        write_rank_db)
from repro.serve import (IngestConfig, QueryClient, QueryService,
                         ServiceConfig, ServiceError)

_NS = 1_000_000_000
SUITE_QUERY = Query(metrics=("k_stall", "m_duration"), group_by="src_rank",
                    reducers=("moments", "quantile"))


@pytest.fixture(scope="module")
def growing(tmp_path_factory):
    """A live profiler run: snapshots at 12 s, the rest arriving later
    in batches at the same DB paths (fresh larger rowids)."""
    spec = SyntheticSpec(n_ranks=2, kernels_per_rank=4000,
                         memcpys_per_rank=500, duration_s=24.0,
                         n_anomaly_windows=2, seed=7)
    ds = generate_synthetic(spec)
    t0 = int(ds.traces[0].kernels.start.min())
    cutoff = (t0 // _NS) * _NS + 12 * _NS
    return ds, cutoff


def _snapshot_store(ds, cutoff, root):
    db_dir = os.path.join(str(root), "dbs")
    os.makedirs(db_dir, exist_ok=True)
    paths = [os.path.join(db_dir, f"rank{tr.rank}.sqlite")
             for tr in ds.traces]
    for tr, p in zip(ds.traces, paths):
        write_rank_db(p, truncate_trace(tr, cutoff))
    store_dir = os.path.join(str(root), "store")
    run_generation(paths, store_dir, n_ranks=2)
    return paths, store_dir


def _grow(ds, paths, cutoff):
    for tr, p in zip(ds.traces, paths):
        append_rank_db(p, trace_remainder(tr, cutoff))


def _assert_identical_to_cold_rebuild(store_dir, paths, root):
    """The streamed store answers the full reducer suite bit-identically
    to a cold ``run_generation`` from the final DBs."""
    cold = os.path.join(str(root), "cold")
    run_generation(paths, cold, n_ranks=2)
    a = run_aggregation(store_dir, query=SUITE_QUERY)
    b = run_aggregation(cold, query=SUITE_QUERY)
    for f in ("count", "sum", "sumsq", "min", "max"):
        np.testing.assert_array_equal(getattr(a.grouped, f),
                                      getattr(b.grouped, f))
    np.testing.assert_array_equal(a.group_keys, b.group_keys)
    np.testing.assert_array_equal(a.reduced["quantile"].counts,
                                  b.reduced["quantile"].counts)


# --- deterministic ingest ticks (no threads: submit + drain_once) ----------

def test_ingest_tick_rides_pipeline_and_diffs_fences(growing, tmp_path):
    """One ingest tick through the admission -> exec -> commit pipeline:
    append provenance lands on the pending, the fence queries run as
    owned lanes of the SAME tick, a fence event is published, and a
    second tick with no growth publishes nothing new."""
    ds, cutoff = growing
    paths, store_dir = _snapshot_store(ds, cutoff, tmp_path)
    svc = QueryService(store_dir, ServiceConfig(tick_ms=1.0))
    ing = svc.ensure_ingestor(IngestConfig())
    assert ing.attach(paths) == [os.path.abspath(p) for p in paths]
    # resumed watermarks: the manifest already covers the snapshot rows
    assert all(w > (0, 0) for w in ing.watermarks().values())
    assert ing.poll_once() == []            # no growth yet

    _grow(ds, paths, cutoff)
    assert sorted(ing.poll_once()) == sorted(ing.attached())
    p = ing.submit(t_detect=time.monotonic())
    assert svc.drain_once(block_s=0.0) == 1
    assert p.error is None
    info = p.tick_info["ingest"]
    assert p.tick_info["kind"] == "ingest"
    assert info["rows_ingested"] > 0
    assert info["dirty_shards"] or info["n_new_shards"]
    assert info["event_to_fence_ms"] > 0.0
    # the tick's commit published to the hub and advanced watermarks
    events = ing.hub.events_since(0)
    assert len(events) == 1 and events[0]["kind"] in ("fence", "ingest")
    assert events[0]["ingest"]["rows_ingested"] == info["rows_ingested"]
    assert ing.poll_once() == []            # fully caught up

    # no growth -> submit ingests zero rows and publishes nothing
    p2 = ing.submit(t_detect=time.monotonic())
    assert svc.drain_once(block_s=0.0) == 1
    assert p2.error is None
    assert p2.tick_info["ingest"]["rows_ingested"] == 0
    assert ing.hub.events_since(events[0]["seq"]) == []

    st = ing.stats()
    assert st["ingest_ticks"] == 2
    assert st["rows_ingested"] == info["rows_ingested"]
    assert st["event_to_fence_p99_ms"] > 0.0
    _assert_identical_to_cold_rebuild(store_dir, paths, tmp_path)


def test_interrupted_ingest_tick_recovers_via_journal(growing, tmp_path,
                                                      monkeypatch):
    """A tick crashing mid-commit (after the intent journal, some staged
    shards published, some not) fails THAT tick only; the next tick
    rolls the journal FORWARD and re-reads zero rows — the journaled
    watermarks already cover the ingested batch, so nothing is
    double-ingested and the store ends bit-identical to a cold
    rebuild."""
    ds, cutoff = growing
    paths, store_dir = _snapshot_store(ds, cutoff, tmp_path)
    svc = QueryService(store_dir, ServiceConfig(tick_ms=1.0))
    ing = svc.ensure_ingestor(IngestConfig())
    ing.attach(paths)
    _grow(ds, paths, cutoff)

    real = TraceStore.commit_staged_shard
    calls = {"n": 0}

    def crashing_commit(self, idx):
        calls["n"] += 1
        if calls["n"] > 1:
            raise RuntimeError("injected crash mid-commit")
        return real(self, idx)

    monkeypatch.setattr(TraceStore, "commit_staged_shard",
                        crashing_commit)
    p = ing.submit(t_detect=time.monotonic())
    assert svc.drain_once(block_s=0.0) == 1
    assert p.error is not None
    assert p.error[0] == 500 and p.error[1] == "ingest_failed"
    assert "injected crash" in p.error[2]
    assert calls["n"] > 1                   # crashed mid-commit…
    intent = os.path.join(store_dir, "append_intent.json")
    assert os.path.exists(intent)           # …journal survives the tick
    assert ing.stats()["errors"] == 1

    monkeypatch.setattr(TraceStore, "commit_staged_shard", real)
    p2 = ing.submit(t_detect=time.monotonic())
    assert svc.drain_once(block_s=0.0) == 1
    assert p2.error is None
    info = p2.tick_info["ingest"]
    assert info["recovered"] is True
    assert info["rows_ingested"] == 0       # rolled forward, not re-read
    assert not os.path.exists(intent)
    st = ing.stats()
    assert st["recoveries"] == 1
    assert ing.poll_once() == []
    _assert_identical_to_cold_rebuild(store_dir, paths, tmp_path)


def test_live_writer_mid_tail_no_duplicate_no_lost_rows(growing,
                                                        tmp_path):
    """Writers keep appending batches WHILE the tailer polls and ingest
    ticks execute — rows landing mid-append stay above the dispatched
    watermark and ride a later tick. After quiesce the streamed store
    is bit-identical to a cold rebuild of the final DBs: any duplicated
    or lost row would break the count equality."""
    ds, cutoff = growing
    paths, store_dir = _snapshot_store(ds, cutoff, tmp_path)
    svc = QueryService(store_dir, ServiceConfig(
        tick_ms=1.0, ingest=IngestConfig(poll_ms=5.0)))
    ing = svc.ensure_ingestor()
    ing.attach(paths)
    svc.start(serve_http=False)
    try:
        cuts = [cutoff + k * 3 * _NS for k in range(1, 4)] + [None]

        def writer():
            lo = cutoff
            for hi in cuts:
                for tr, p in zip(ds.traces, paths):
                    batch = (trace_remainder(tr, lo) if hi is None else
                             trace_remainder(truncate_trace(tr, hi), lo))
                    append_rank_db(p, batch)
                lo = hi
                time.sleep(0.02)        # overlap writes with ingests

        threads = [threading.Thread(target=writer)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert ing.quiesce(timeout_s=60.0)
        st = ing.stats()
        assert st["errors"] == 0
        assert st["ingest_ticks"] >= 1
    finally:
        svc.stop()
    _assert_identical_to_cold_rebuild(store_dir, paths, tmp_path)


# --- the v1 HTTP surface ---------------------------------------------------

def _raw_get(port, path):
    import json as _json
    import urllib.error
    import urllib.request
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}{path}", timeout=30) as r:
            return r.status, dict(r.headers), _json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), _json.loads(e.read())


def test_v1_routes_envelope_and_legacy_deprecation(growing, tmp_path):
    """Every v1 endpoint answers; every error speaks the shared
    envelope; the legacy unversioned aliases answer identically but
    stamped ``Deprecation: true`` with a successor-version ``Link``."""
    ds, cutoff = growing
    paths, store_dir = _snapshot_store(ds, cutoff, tmp_path)
    svc = QueryService(store_dir,
                       ServiceConfig(tick_ms=2.0, port=0)).start()
    c = QueryClient(port=svc.cfg.port)
    try:
        assert c.wait_healthy(timeout_s=10.0)
        assert c.healthz()["api"] == "v1"
        assert c.stats()["ingest"] is None

        r = c.query(Query(metrics=("k_stall",), group_by="m_kind"))
        assert r["n_samples"] > 0

        # legacy aliases: same answers, Deprecation + Link headers
        for path in ("/healthz", "/stats"):
            status, hdr, _ = _raw_get(svc.cfg.port, path)
            assert status == 200
            assert hdr.get("Deprecation") == "true"
            assert "successor-version" in hdr.get("Link", "")
        status, hdr, _ = _raw_get(svc.cfg.port, "/v1/healthz")
        assert status == 200 and "Deprecation" not in hdr

        # the shared error envelope, across routes and codes
        status, _, body = _raw_get(svc.cfg.port, "/v1/nope")
        assert status == 404
        assert body["error"]["code"] == "not_found"
        with pytest.raises(ServiceError) as ei:
            c.fences(since=0, timeout_s=0.2)
        assert ei.value.status == 409
        assert ei.value.code == "no_ingest_plane"
        with pytest.raises(ServiceError) as ei:
            c.attach([])                # malformed body
        assert ei.value.status == 400
        assert ei.value.code == "bad_request"
        with pytest.raises(ServiceError) as ei:
            c.query({"metrics": ["k_stall"], "interval_ns": "bogus"})
        assert ei.value.code == "bad_request"
    finally:
        svc.stop()


def test_fence_push_received_over_http(growing, tmp_path):
    """The facade round trip: ``VariabilityPipeline.stream`` serves a
    store already tailing its rank DBs; a live write produces a fence
    event a long-polling ``QueryClient`` receives, ingest provenance
    shows up under /v1/stats, and detach stops the tailing."""
    ds, cutoff = growing
    paths, store_dir = _snapshot_store(ds, cutoff, tmp_path)
    pipe = VariabilityPipeline(PipelineConfig(n_ranks=2))
    svc = pipe.stream(store_dir, paths,
                      ingest=IngestConfig(poll_ms=5.0), tick_ms=2.0)
    c = QueryClient(port=svc.cfg.port)
    try:
        assert c.wait_healthy(timeout_s=10.0)
        assert c.healthz()["ingest"] is True
        _grow(ds, paths, cutoff)
        body = c.fences(since=0, timeout_s=30.0)
        assert body["events"], "no fence event within the long poll"
        e = body["events"][0]
        assert e["kind"] in ("fence", "ingest")
        assert e["ingest"]["rows_ingested"] > 0
        assert body["next_since"] >= e["seq"]
        # caught up: a fresh long poll with a short timeout is empty
        again = c.fences(since=body["next_since"], timeout_s=0.2)
        assert again["events"] == []
        assert svc.ingestor.quiesce(timeout_s=60.0)
        st = c.stats()["ingest"]
        assert st["rows_ingested"] > 0
        assert st["event_to_fence_p99_ms"] > 0.0
        assert st["errors"] == 0
        out = c.detach(paths)
        assert out["tailing"] == []
    finally:
        svc.stop()
        pipe.close()
    _assert_identical_to_cold_rebuild(store_dir, paths, tmp_path)


# --- legacy argument spellings: warn, but mint identical keys --------------

def test_legacy_metric_args_warn_and_mint_identical_cache_keys(tmp_path):
    """The migration contract: old-style (metrics, group_by, reducers)
    arguments emit DeprecationWarning but produce byte-identical
    summary AND partial keys to the Query spelling — warm caches stay
    warm across the API migration."""
    store = TraceStore(str(tmp_path))
    q = Query(metrics=("k_stall",), group_by="m_kind",
              reducers=("moments", "quantile"))
    pk = (0, 10, 10)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy_s = store.summary_key(pk, metrics=["k_stall"],
                                     group_by="m_kind",
                                     reducers=("moments", "quantile"))
    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy_p = store.partial_key(pk, metrics=["k_stall"],
                                     group_by="m_kind",
                                     reducers=("moments", "quantile"))
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # Query spelling: no warning
        assert store.summary_key(pk, query=q) == legacy_s
        assert store.partial_key(pk, query=q) == legacy_p


def test_legacy_run_aggregation_args_warn_and_match_query(growing,
                                                          tmp_path):
    ds, cutoff = growing
    paths, store_dir = _snapshot_store(ds, cutoff, tmp_path)
    with pytest.warns(DeprecationWarning, match="legacy spelling"):
        a = run_aggregation(store_dir, metric="k_stall")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        b = run_aggregation(store_dir, query=Query(metrics=("k_stall",)))
    np.testing.assert_array_equal(a.stats.count, b.stats.count)
    np.testing.assert_array_equal(a.stats.sum, b.stats.sum)
    assert b.from_cache                     # the legacy run warmed it
