"""Reducer-framework correctness core:

  * merge is associative + commutative for EVERY registered reducer (the
    property the round-robin / process / jax psum reductions rely on) —
    property-tested under hypothesis when installed, and always covered
    by deterministic seeded sweeps;
  * the quantile sketch answers P50/P95/P99 within its stated relative
    error bound vs np.percentile on the same rows;
  * a pre-refactor (old SUMMARY_VERSION) summary payload is a cache MISS,
    never a crash;
  * the generic round-robin merge and payload round-trip work for the
    quantile sketch exactly as for the moments.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:           # degrade property sweeps to skips
    HAVE_HYPOTHESIS = False

from repro.core.anomaly import anomalous_bins, score_values
from repro.core.aggregation import round_robin_merge, run_aggregation
from repro.core.reducers import (BinStats, QuantileSketch,
                                 QUANTILE_REL_ERR, REDUCER_REGISTRY,
                                 bucket_of, get_reducer,
                                 normalize_reducers, N_BUCKETS)
from repro.core.sharding import ShardPlan
from repro.core.tracestore import SUMMARY_VERSION, TraceStore

ALL_REDUCERS = sorted(REDUCER_REGISTRY)


def _grouped_state(name, seed, n=300, n_bins=13, n_groups=3, n_metrics=2):
    rng = np.random.default_rng(seed)
    plan = ShardPlan(0, 10_000, n_bins)
    ts = rng.integers(0, 10_000, n)
    vals = np.abs(rng.normal(5000, 2000, (n, n_metrics)))
    gid = rng.integers(0, n_groups, n)
    return get_reducer(name).bin_grouped(ts, vals, gid, n_groups, plan)


# fields that are float sums (associative only up to rounding); counts,
# histogram counts and min/max are exact under any merge order.
_SUM_FIELDS = {"sum", "sumsq"}


def _assert_state_equal(a, b, exact=True):
    assert type(a) is type(b)
    for f in a.fields:
        if exact or f not in _SUM_FIELDS:
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
        else:
            np.testing.assert_allclose(getattr(a, f), getattr(b, f),
                                       rtol=1e-12)


@pytest.mark.parametrize("name", ALL_REDUCERS)
def test_merge_associative_commutative_seeded(name):
    a, b, c = (_grouped_state(name, s) for s in (0, 1, 2))
    # commutativity of + is exact in IEEE float; associativity only up to
    # rounding for the float sums (count/min/max/histogram stay exact).
    _assert_state_equal(a.merge(b), b.merge(a))
    _assert_state_equal(a.merge(b).merge(c), a.merge(b.merge(c)),
                        exact=False)


@pytest.mark.parametrize("name", ALL_REDUCERS)
def test_partition_merge_equals_serial(name):
    """Binning any partition of the samples and merging gives EXACTLY the
    one-shot result (the mergeable-reducer contract)."""
    rng = np.random.default_rng(7)
    n, n_bins, n_groups = 400, 17, 4
    plan = ShardPlan(0, 10_000, n_bins)
    ts = rng.integers(0, 10_000, n)
    vals = np.abs(rng.normal(100, 40, (n, 2)))
    gid = rng.integers(0, n_groups, n)
    cls = get_reducer(name)
    serial = cls.bin_grouped(ts, vals, gid, n_groups, plan)
    merged = cls.zeros(n_bins, (n_groups, 2))
    for idx in np.split(np.arange(n), [50, 120, 340]):
        merged = merged.merge(
            cls.bin_grouped(ts[idx], vals[idx], gid[idx], n_groups, plan))
    _assert_state_equal(merged, serial, exact=False)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(name=st.sampled_from(ALL_REDUCERS), parts=st.integers(1, 6),
           n=st.integers(1, 300), seed=st.integers(0, 999))
    def test_reducer_merge_property(name, parts, n, seed):
        """Property: any partitioning + any merge tree == one shot."""
        rng = np.random.default_rng(seed)
        plan = ShardPlan(0, 5_000, 11)
        ts = rng.integers(0, 5_000, n)
        vals = np.abs(rng.normal(50, 20, (n, 1)))
        gid = rng.integers(0, 2, n)
        cls = get_reducer(name)
        serial = cls.bin_grouped(ts, vals, gid, 2, plan)
        cut = (np.sort(rng.integers(0, n, parts - 1)) if parts > 1
               else [])
        merged = cls.zeros(plan.n_shards, (2, 1))
        pieces = np.split(np.arange(n), cut)
        for idx in rng.permutation(len(pieces)):
            merged = merged.merge(cls.bin_grouped(
                ts[pieces[idx]], vals[pieces[idx]], gid[pieces[idx]], 2,
                plan))
        _assert_state_equal(merged, serial, exact=False)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_reducer_merge_property():
        pass


def test_round_robin_merge_generic_over_quantile():
    parts = [_grouped_state("quantile", s) for s in range(5)]
    rr, owned = round_robin_merge(parts, parts[0].n_bins)
    plain = QuantileSketch.zeros(parts[0].n_bins, parts[0].trailing)
    for p in parts:
        plain = plain.merge(p)
    _assert_state_equal(rr, plain)
    for r, ids in enumerate(owned):
        if len(ids):
            assert ids[0] == r


def test_quantile_error_bound_vs_percentile():
    """The sketch's stated contract: P50/P95/P99 within QUANTILE_REL_ERR
    of np.percentile for in-range samples (plus a whisker for the rank
    convention on finite samples)."""
    rng = np.random.default_rng(3)
    plan = ShardPlan(0, 1, 1)          # one bin: the pure-sketch question
    for scale, shape in ((2000.0, 1.0), (50.0, 0.3), (1e6, 2.0)):
        x = rng.lognormal(np.log(scale), shape, 5000)
        sk = QuantileSketch.bin_grouped(
            np.zeros(len(x), np.int64), x[:, None],
            np.zeros(len(x), np.int64), 1, plan)
        sk1 = sk.merge_groups().select_metric(0)
        for q in (0.50, 0.95, 0.99):
            est = float(sk1.quantile(q)[0])
            true = float(np.percentile(x, 100 * q))
            rel = abs(est - true) / true
            assert rel <= QUANTILE_REL_ERR * 1.25 + 1e-3, \
                (q, scale, shape, est, true, rel)


def test_quantile_iqr_and_empty_bins():
    rng = np.random.default_rng(4)
    plan = ShardPlan(0, 100, 4)
    x = np.abs(rng.normal(1000, 300, 500))
    ts = rng.integers(0, 50, 500)      # bins 2,3 stay empty
    sk = QuantileSketch.bin_grouped(ts, x[:, None],
                                    np.zeros(500, np.int64), 1, plan)
    sk1 = sk.merge_groups().select_metric(0)
    assert sk1.quantile(0.5)[3] == 0.0          # empty bin -> 0
    assert np.all(sk1.iqr() >= 0.0)
    occ = sk1.total() > 0
    q1, q3 = sk1.quantile(0.25), sk1.quantile(0.75)
    np.testing.assert_allclose(sk1.iqr()[occ], (q3 - q1)[occ])


def test_bucket_of_contract():
    assert bucket_of(np.asarray([0.0]))[0] == 0          # underflow
    assert bucket_of(np.asarray([-5.0]))[0] == 0         # negatives clamp
    assert bucket_of(np.asarray([1e30]))[0] == N_BUCKETS - 1   # overflow
    v = np.asarray([1.0, 2.0, 4.0])
    b = bucket_of(v)
    assert b[1] - b[0] == b[2] - b[1]                    # log-uniform


def test_payload_round_trip_both_reducers():
    for name in ALL_REDUCERS:
        st_ = _grouped_state(name, 9)
        back = get_reducer(name).from_payload(st_.to_payload())
        _assert_state_equal(st_, back)


def test_normalize_reducers():
    assert normalize_reducers(()) == ("moments",)
    assert normalize_reducers(("quantile",)) == ("moments", "quantile")
    assert normalize_reducers(("quantile", "moments", "quantile")) == \
        ("moments", "quantile")
    with pytest.raises(KeyError):
        normalize_reducers(("nope",))


def test_pipeline_config_auto_includes_quantile():
    """A quantile-family anomaly_score must pull the sketch into the
    suite up front — not fail after a full generate+aggregate."""
    from repro.core import PipelineConfig
    assert PipelineConfig().reducer_suite == ("moments",)
    assert PipelineConfig(anomaly_score="p99").reducer_suite == \
        ("moments", "quantile")
    assert PipelineConfig(anomaly_score="iqr").reducer_suite == \
        ("moments", "quantile")
    assert PipelineConfig(anomaly_score="std").reducer_suite == \
        ("moments",)


def test_score_values_dispatch():
    m = _grouped_state("moments", 11)
    q = _grouped_state("quantile", 11)
    assert score_values(m, "mean").ndim == 1
    assert score_values(q, "p95").ndim == 1
    assert score_values(q, "iqr").ndim == 1
    with pytest.raises(ValueError):
        score_values(m, "p99")          # moments can't answer quantiles
    with pytest.raises(ValueError):
        score_values(q, "mean")         # sketch can't answer moments
    with pytest.raises(ValueError):
        score_values(m, "nope")
    rep = anomalous_bins(q, score="p99")
    assert rep.scores.shape == (q.n_bins,)


@pytest.fixture()
def tiny_store(small_dataset, tmp_path):
    from repro.core import run_generation
    ds, paths = small_dataset
    out = str(tmp_path / "store")
    run_generation(paths, out, n_ranks=2)
    return out


def test_old_version_summary_is_miss_not_crash(tiny_store):
    """Regression: a summary payload written by an older engine version
    (e.g. a pre-refactor v1 npz without the reducers array) must be
    treated as a cache miss — recomputed, not crashed on."""
    cold = run_aggregation(tiny_store, metrics=["k_stall"])
    assert not cold.from_cache
    store = TraceStore(tiny_store)
    keys = store.summary_keys()
    assert keys
    for key in keys:
        payload = store.read_summary(key)
        # forge a pre-refactor payload AT THE CURRENT KEY: v1 version
        # stamp, no "reducers" array, bare moment fields only.
        old = {k: v for k, v in payload.items()
               if not k.startswith("quantile__") and k != "reducers"}
        old["version"] = np.asarray(SUMMARY_VERSION - 1, np.int64)
        store.write_summary(key, old)
    again = run_aggregation(tiny_store, metrics=["k_stall"])
    assert not again.from_cache            # miss, recomputed
    np.testing.assert_array_equal(cold.stats.count, again.stats.count)
    warm = run_aggregation(tiny_store, metrics=["k_stall"])
    assert warm.from_cache                 # fresh entry now serves


def test_summary_key_depends_on_reducer_suite(tiny_store):
    store = TraceStore(tiny_store)
    plan = (0, 10, 5)
    a = store.summary_key(plan, ["k_stall"], None)
    b = store.summary_key(plan, ["k_stall"], None,
                          reducers=("moments", "quantile"))
    assert a != b
