"""Distributed statistics ≡ serial statistics (the paper's correctness
core): partial-moment merges are associative/commutative and the
round-robin collaborative reduction is exact."""

import numpy as np
import pytest

# Degrade to skips (not a collection error) when hypothesis is absent; the
# CI dev extra installs it. Non-property coverage of the aggregation engine
# lives in test_multimetric.py / test_tracestore.py, which need no
# hypothesis.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import (BinStats, bin_samples,
                                    round_robin_merge)
from repro.core.sharding import ShardPlan


def _random_samples(rng, n, t0, t1):
    ts = rng.integers(t0, t1, size=n)
    vals = rng.normal(50, 20, size=n)
    return ts, vals


def test_bin_samples_matches_numpy_groupby():
    rng = np.random.default_rng(0)
    plan = ShardPlan(0, 1000, 10)
    ts, vals = _random_samples(rng, 500, 0, 1000)
    stats = bin_samples(ts, vals, plan)
    bins = plan.shard_of(ts)
    for b in range(10):
        sel = vals[bins == b]
        assert stats.count[b] == len(sel)
        if len(sel):
            np.testing.assert_allclose(stats.sum[b], sel.sum(), rtol=1e-9)
            np.testing.assert_allclose(stats.min[b], sel.min())
            np.testing.assert_allclose(stats.max[b], sel.max())
            np.testing.assert_allclose(stats.std[b], sel.std(), atol=1e-8)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 400), parts=st.integers(1, 7),
       seed=st.integers(0, 999))
def test_partition_merge_equals_serial(n, parts, seed):
    """Property: binning any partition of the samples and merging gives
    EXACTLY the serial result (Chan et al. mergeable moments)."""
    rng = np.random.default_rng(seed)
    plan = ShardPlan(0, 10_000, 23)
    ts, vals = _random_samples(rng, n, 0, 10_000)
    serial = bin_samples(ts, vals, plan)

    cut = np.sort(rng.integers(0, n, size=parts - 1)) if parts > 1 else []
    pieces = np.split(np.arange(n), cut)
    merged = BinStats.zeros(plan.n_shards)
    for idx in pieces:
        merged = merged.merge(bin_samples(ts[idx], vals[idx], plan))

    np.testing.assert_allclose(merged.count, serial.count)
    np.testing.assert_allclose(merged.sum, serial.sum, rtol=1e-12)
    np.testing.assert_allclose(merged.sumsq, serial.sumsq, rtol=1e-12)
    np.testing.assert_array_equal(merged.min, serial.min)
    np.testing.assert_array_equal(merged.max, serial.max)


@settings(max_examples=20, deadline=None)
@given(p=st.integers(1, 9), seed=st.integers(0, 99))
def test_round_robin_merge_equals_plain_merge(p, seed):
    rng = np.random.default_rng(seed)
    plan = ShardPlan(0, 5000, 17)
    partials = []
    for _ in range(p):
        ts, vals = _random_samples(rng, 100, 0, 5000)
        partials.append(bin_samples(ts, vals, plan))
    rr, owned = round_robin_merge(partials, plan.n_shards)

    plain = BinStats.zeros(plan.n_shards)
    for part in partials:
        plain = plain.merge(part)
    np.testing.assert_allclose(rr.count, plain.count)
    np.testing.assert_allclose(rr.sum, plain.sum, rtol=1e-12)
    np.testing.assert_array_equal(rr.min, plain.min)
    # ownership is the cyclic round-robin of the paper
    for r, ids in enumerate(owned):
        if len(ids):
            assert ids[0] == r


def test_merge_is_commutative():
    rng = np.random.default_rng(3)
    plan = ShardPlan(0, 100, 5)
    a = bin_samples(*_random_samples(rng, 50, 0, 100), plan)
    b = bin_samples(*_random_samples(rng, 60, 0, 100), plan)
    ab, ba = a.merge(b), b.merge(a)
    np.testing.assert_array_equal(ab.sum, ba.sum)
    np.testing.assert_array_equal(ab.min, ba.min)


def test_empty_bins_have_identity_stats():
    plan = ShardPlan(0, 100, 4)
    stats = bin_samples(np.asarray([5]), np.asarray([2.0]), plan)
    assert stats.count[3] == 0
    assert stats.finite_min()[3] == 0.0 and stats.finite_max()[3] == 0.0
    assert np.isinf(stats.min[3])
