"""Model-substrate correctness: attention vs dense oracle, SSD vs naive
recurrence, decode ≡ prefill, MoE invariants, RoPE properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (AttnConfig, attn_decode, attn_forward,
                                    attn_init, attn_init_cache,
                                    chunked_attention)
from repro.models.layers import apply_mrope, apply_rope
from repro.models.moe import MoEConfig, moe_forward, moe_init
from repro.models.ssm import (SSMConfig, ssd_scan, ssm_decode, ssm_forward,
                              ssm_init, ssm_init_cache)

RNG = np.random.default_rng(0)


def _dense_attention(q, k, v, causal=True, window=0):
    """O(S²) oracle."""
    b, s, h, hd = q.shape
    kv_h = k.shape[2]
    g = h // kv_h
    qg = q.reshape(b, s, kv_h, g, hd)
    scores = np.einsum("bqkgh,bskh->bkgqs", qg, k) / np.sqrt(hd)
    qi = np.arange(s)[:, None]
    ki = np.arange(s)[None, :]
    mask = np.ones((s, s), bool)
    if causal:
        mask &= qi >= ki
    if window > 0:
        mask &= (qi - ki) < window
    scores = np.where(mask, scores, -1e30)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    o = np.einsum("bkgqs,bskh->bqkgh", p, v)
    return o.reshape(b, s, h, hd)


@pytest.mark.parametrize("s,h,kv,window,causal", [
    (33, 4, 2, 0, True),
    (64, 4, 4, 0, True),
    (50, 8, 2, 16, True),     # SWA
    (40, 4, 4, 0, False),     # encoder
])
def test_chunked_attention_matches_dense(s, h, kv, window, causal):
    b, hd = 2, 16
    q = RNG.normal(size=(b, s, h, hd)).astype(np.float32)
    k = RNG.normal(size=(b, s, kv, hd)).astype(np.float32)
    v = RNG.normal(size=(b, s, kv, hd)).astype(np.float32)
    out = chunked_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=causal, window=window,
                            q_chunk=16, kv_chunk=16)
    ref = _dense_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


def test_chunked_attention_chunk_invariance():
    b, s, h, hd = 1, 48, 2, 8
    q = jnp.asarray(RNG.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, hd)), jnp.float32)
    a = chunked_attention(q, k, v, q_chunk=8, kv_chunk=8)
    c = chunked_attention(q, k, v, q_chunk=48, kv_chunk=48)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-4,
                               atol=1e-4)


def test_gqa_decode_matches_prefill_next_token():
    cfg = AttnConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                     q_chunk=8, kv_chunk=8)
    params = attn_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 12, 32)), jnp.float32)
    full, _ = attn_forward(params, x, cfg)
    _, pre = attn_forward(params, x[:, :11], cfg)
    cache = {"k": jnp.pad(pre["k"], ((0, 0), (0, 5), (0, 0), (0, 0))),
             "v": jnp.pad(pre["v"], ((0, 0), (0, 5), (0, 0), (0, 0)))}
    dec, _ = attn_decode(params, x[:, 11:12], cache, cfg,
                         jnp.int32(11))
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, 11]),
                               rtol=1e-4, atol=1e-4)


def test_swa_ring_decode_matches_full_window():
    """Ring-buffer decode over window w ≡ attention over the last w
    tokens."""
    w = 8
    cfg = AttnConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                     window=w, q_chunk=8, kv_chunk=8)
    params = attn_init(jax.random.PRNGKey(1), cfg)
    s = 21
    x = jnp.asarray(RNG.normal(size=(1, s, 32)), jnp.float32)
    full, _ = attn_forward(params, x, cfg)

    # build ring cache by decoding tokens one by one
    cache = attn_init_cache(cfg, 1, max_len=64, dtype=jnp.float32)
    for t in range(s):
        dec, cache = attn_decode(params, x[:, t:t + 1], cache, cfg,
                                 jnp.int32(t))
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=1e-4, atol=1e-4)


def test_mla_decode_matches_prefill():
    cfg = AttnConfig(d_model=48, n_heads=4, n_kv_heads=4, head_dim=16,
                     q_lora_rank=24, kv_lora_rank=16, qk_nope_dim=8,
                     qk_rope_dim=8, v_head_dim=8, q_chunk=8, kv_chunk=8)
    params = attn_init(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 10, 48)), jnp.float32)
    full, _ = attn_forward(params, x, cfg)
    _, pre = attn_forward(params, x[:, :9], cfg)
    cache = {"latent": jnp.pad(pre["latent"], ((0, 0), (0, 3), (0, 0))),
             "k_rope": jnp.pad(pre["k_rope"], ((0, 0), (0, 3), (0, 0)))}
    dec, _ = attn_decode(params, x[:, 9:10], cache, cfg, jnp.int32(9))
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, 9]),
                               rtol=1e-3, atol=1e-3)


# --- SSD -------------------------------------------------------------------------

def test_ssd_scan_matches_naive_recurrence():
    b, s, H, P, G, N = 2, 29, 4, 8, 2, 16
    xs = jnp.asarray(RNG.normal(size=(b, s, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.1, size=(b, s, H)), jnp.float32)
    A_log = jnp.asarray(RNG.uniform(-1, 1, size=(H,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, s, G, N)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, s, G, N)), jnp.float32)
    D = jnp.asarray(RNG.normal(size=(H,)), jnp.float32)
    y, hf = ssd_scan(xs, dt, A_log, B, C, D, chunk=8)

    A = -np.exp(np.asarray(A_log))
    hg = H // G
    h = np.zeros((b, H, P, N))
    ys = np.zeros((b, s, H, P))
    for t in range(s):
        a = np.exp(np.asarray(dt)[:, t] * A)
        Bh = np.repeat(np.asarray(B)[:, t], hg, axis=1)
        Ch = np.repeat(np.asarray(C)[:, t], hg, axis=1)
        xb = np.asarray(dt)[:, t][..., None] * np.asarray(xs)[:, t]
        h = a[..., None, None] * h + xb[..., None] * Bh[:, :, None, :]
        ys[:, t] = np.einsum("bhpn,bhn->bhp", h, Ch) + \
            np.asarray(D)[None, :, None] * np.asarray(xs)[:, t]
    np.testing.assert_allclose(np.asarray(y), ys, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), h, rtol=1e-4, atol=1e-4)


def test_ssd_chunk_invariance():
    b, s, H, P, G, N = 1, 40, 2, 4, 1, 8
    args = (jnp.asarray(RNG.normal(size=(b, s, H, P)), jnp.float32),
            jnp.asarray(RNG.uniform(0.01, 0.1, (b, s, H)), jnp.float32),
            jnp.asarray(RNG.uniform(-1, 1, (H,)), jnp.float32),
            jnp.asarray(RNG.normal(size=(b, s, G, N)), jnp.float32),
            jnp.asarray(RNG.normal(size=(b, s, G, N)), jnp.float32),
            jnp.asarray(RNG.normal(size=(H,)), jnp.float32))
    y8, h8 = ssd_scan(*args, chunk=8)
    y40, h40 = ssd_scan(*args, chunk=40)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y40),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h8), np.asarray(h40),
                               rtol=1e-4, atol=1e-4)


def test_ssm_block_decode_matches_forward():
    cfg = SSMConfig(d_model=32, d_state=16, head_dim=8, n_groups=2,
                    chunk=8)
    params = ssm_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 14, 32)), jnp.float32)
    out, _ = ssm_forward(params, x, cfg)
    # replay token-by-token from scratch
    cache = ssm_init_cache(cfg, 2, dtype=jnp.float32)
    for t in range(14):
        dec, cache = ssm_decode(params, x[:, t:t + 1], cache, cfg)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(out[:, -1]),
                               rtol=1e-3, atol=1e-3)


# --- MoE -------------------------------------------------------------------------

def test_moe_routes_every_token_with_ample_capacity():
    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=2,
                    capacity_factor=4.0)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(3, 8, 16)), jnp.float32)
    out, m = moe_forward(params, x, cfg)
    assert out.shape == x.shape
    assert float(m["dropped"]) == 0.0
    assert np.isfinite(np.asarray(out)).all()


def test_moe_capacity_drops_counted():
    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=8, top_k=4,
                    capacity_factor=0.25)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 32, 16)), jnp.float32)
    out, m = moe_forward(params, x, cfg)
    assert float(m["dropped"]) > 0.0


def test_moe_shared_experts_add_dense_path():
    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=1, n_shared=2,
                    capacity_factor=4.0)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(1, 4, 16)), jnp.float32)
    out, _ = moe_forward(params, x, cfg)
    # zeroing shared experts must change the output
    params2 = jax.tree.map(lambda a: a, params)
    params2["shared"] = jax.tree.map(jnp.zeros_like, params["shared"])
    out2, _ = moe_forward(params2, x, cfg)
    assert not np.allclose(np.asarray(out), np.asarray(out2))


# --- positions --------------------------------------------------------------------

def test_rope_preserves_norm_and_relativity():
    x = jnp.asarray(RNG.normal(size=(1, 6, 2, 16)), jnp.float32)
    pos = jnp.arange(6)[None, :]
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = np.asarray(RNG.normal(size=(16,)), np.float32)
    k = np.asarray(RNG.normal(size=(16,)), np.float32)

    def dot_at(p, d):
        qk = jnp.stack([jnp.asarray(q), jnp.asarray(k)])[None, :, None, :]
        rot = apply_rope(qk, jnp.asarray([[p, p + d]]))
        r = np.asarray(rot)[0, :, 0, :]
        return float((r[0] * r[1]).sum())
    np.testing.assert_allclose(dot_at(0, 3), dot_at(7, 3), rtol=1e-4)


def test_partial_rope_leaves_tail_unrotated():
    x = jnp.asarray(RNG.normal(size=(1, 4, 1, 16)), jnp.float32)
    y = apply_rope(x, jnp.arange(4)[None, :], rotary_fraction=0.5)
    np.testing.assert_array_equal(np.asarray(y)[..., 8:],
                                  np.asarray(x)[..., 8:])


def test_mrope_matches_rope_when_positions_equal():
    """With t=h=w ids equal, M-RoPE == standard RoPE."""
    hd = 16
    x = jnp.asarray(RNG.normal(size=(1, 5, 2, hd)), jnp.float32)
    pos = jnp.arange(5)[None, :]
    pos3 = jnp.broadcast_to(pos[:, None, :], (1, 3, 5))
    a = apply_mrope(x, pos3, sections=(3, 3, 2))
    # standard rope in the half-split convention used by mrope
    inv = 1.0 / (10000.0 ** (np.arange(0, hd, 2) / hd))
    ang = np.arange(5)[:, None] * inv[None, :]
    sin, cos = np.sin(ang), np.cos(ang)
    xr = np.asarray(x)
    r1, r2 = xr[..., : hd // 2], xr[..., hd // 2:]
    e1 = r1 * cos[None, :, None, :] - r2 * sin[None, :, None, :]
    e2 = r2 * cos[None, :, None, :] + r1 * sin[None, :, None, :]
    np.testing.assert_allclose(np.asarray(a),
                               np.concatenate([e1, e2], -1),
                               rtol=1e-5, atol=1e-5)
