"""Consolidated partial-pack tests: compaction (planted stale qkeys
dropped, live fingerprints survive), bit-identity of pack-served
partials against the pre-pack per-file layout (byte-identical summary
files), the io_counts-proven fused-batch IO reduction (logical
per-entry counts vs physical pack operations), and thread-safety of the
io_counts tallies under a hammering writer/reader mix."""

import io
import os
import shutil
import threading

import numpy as np
import pytest

from repro.core import (Query, SyntheticSpec, TraceStore,
                        generate_synthetic, run_aggregation,
                        run_generation, run_queries, write_rank_db)
from repro.core.tracestore import pack_filename, partial_filename

METRICS = ["k_stall", "m_duration", "m_bytes"]

QUERIES = [
    Query(metrics=("k_stall",), group_by="m_kind"),
    Query(metrics=("m_duration", "m_bytes"), group_by="m_kind",
          ranks=(0,)),
    Query(metrics=("k_stall", "m_duration"),
          reducers=("moments", "quantile")),
    Query(metrics=("m_bytes",), group_by="k_device"),
]


@pytest.fixture(scope="module")
def base(tmp_path_factory):
    spec = SyntheticSpec(n_ranks=2, kernels_per_rank=3000,
                         memcpys_per_rank=500, duration_s=30.0, seed=23)
    ds = generate_synthetic(spec)
    root = tmp_path_factory.mktemp("pack_base")
    paths = []
    for tr in ds.traces:
        p = str(root / f"rank{tr.rank}.sqlite")
        write_rank_db(p, tr)
        paths.append(p)
    store_dir = str(root / "store")
    run_generation(paths, store_dir, n_ranks=2)
    return store_dir


@pytest.fixture
def store(base, tmp_path):
    dst = str(tmp_path / "s")
    shutil.copytree(base, dst)
    return TraceStore(dst)


# --- compaction -------------------------------------------------------------

def test_compact_drops_planted_stale_qkeys_keeps_live(store):
    """Entries with a stale fingerprint or old engine version are
    dropped by compaction; entries stamped with the live shard
    fingerprint survive byte-for-byte."""
    res = run_aggregation(store, metrics=METRICS, group_by="m_kind")
    qkey = store.partial_key((res.plan.t_start, res.plan.t_end,
                              res.plan.n_shards), METRICS, "m_kind")
    live_before = store.read_partial(0, qkey)
    assert live_before is not None

    from repro.core.query import SUMMARY_VERSION
    store.write_partials(0, {
        "feedfeedfeedfeed": {                  # stale fingerprint
            "version": np.asarray(SUMMARY_VERSION, np.int64),
            "fingerprint": np.asarray([0, 1, 2], np.int64),
            "bins": np.arange(3)},
        "0ddba11deadbeef0": {                  # old engine version
            "version": np.asarray(SUMMARY_VERSION - 1, np.int64),
            "fingerprint": np.asarray(store.stat_shard(0), np.int64),
            "bins": np.arange(3)},
    })
    assert len(store.partial_names(0)) == 3

    dropped = store.compact_pack(0)
    assert dropped == 2
    assert store.partial_names(0) == [partial_filename(0, qkey)]
    live_after = TraceStore(store.root).read_partial(0, qkey)
    for k, v in live_before.items():
        np.testing.assert_array_equal(v, live_after[k])
    assert store.compact_pack(0) == 0          # idempotent no-op


def test_gc_stale_compacts_packs_and_sweeps_legacy_files(store):
    """gc_stale removes a whole pack whose shard file is gone AND any
    pre-pack per-file partial failing the same liveness test."""
    run_aggregation(store, metrics=METRICS)
    n_shards = len(store.shard_indices())
    assert len(store.partial_names()) == n_shards
    # plant a legacy per-file partial with a dead fingerprint
    buf = TraceStore._pack_arrays(
        {"version": np.asarray(4, np.int64)},
        {"version": 4, "fingerprint": [9, 9, 9]})
    b = io.BytesIO()
    np.save(b, buf)
    legacy = os.path.join(store.root, partial_filename(0, "ace0ace0ace0ace0"))
    with open(legacy, "wb") as f:
        f.write(b.getvalue())
    # orphan one pack by deleting its shard file out of band
    os.remove(os.path.join(store.root, f"shard_{n_shards - 1:06d}.npz"))

    store.gc_stale()
    assert not os.path.exists(legacy)
    assert not os.path.exists(
        os.path.join(store.root, pack_filename(n_shards - 1)))
    assert len(store.partial_names()) == n_shards - 1


# --- bit-identity vs the pre-pack per-file layout ---------------------------

def _summary_bytes(root):
    out = {}
    for name in sorted(os.listdir(root)):
        if name.startswith("summary_") and name.endswith(".npz"):
            with open(os.path.join(root, name), "rb") as f:
                out[name] = f.read()
    return out


def test_pack_served_partials_byte_identical_to_per_file_path(store):
    """Regression pin for the layout migration: a store whose partials
    live as pre-pack ``partial_{idx}_{qkey}.npy`` files (the migration
    read path) must merge into byte-identical summary files to the same
    partials served from consolidated packs."""
    for q in QUERIES:
        run_queries(store, [q])
    packs = _summary_bytes(store.root)
    assert packs

    # explode every pack entry into the legacy per-file layout
    legacy_root = store.root
    for idx in store.shard_indices():
        hit = store._load_pack(idx, want_raw=True)
        if hit is None or hit[1] is None:
            continue
        for qkey, (off, ln, _meta) in hit[1].items():
            b = io.BytesIO()
            np.save(b, np.frombuffer(hit[3][off:off + ln], np.uint8))
            with open(os.path.join(legacy_root,
                                   partial_filename(idx, qkey)),
                      "wb") as f:
                f.write(b.getvalue())
        os.remove(os.path.join(legacy_root, pack_filename(idx)))

    legacy_store = TraceStore(legacy_root)
    legacy_store.clear_summaries()
    for q in QUERIES:
        res = run_queries(legacy_store, [q])[0]
        assert res.result.partial_hits > 0     # served from legacy files
    assert legacy_store.io_counts["pack_reads"] == 0
    assert _summary_bytes(legacy_root) == packs


# --- the fused-batch IO claim (io_counts-proven) ----------------------------

def test_fused_batch_pack_io_at_least_1p5x_fewer_ops(store):
    """The acceptance bar: a fused warm re-analysis over a many-lane
    batch performs >= 1.5x fewer physical partial-IO operations than the
    per-file layout would (logical entry counts == what one file per
    (lane, shard) used to cost)."""
    run_queries(store, QUERIES)                # cold: packs written
    w_logical = store.io_counts["partial_writes"]
    w_physical = store.io_counts["pack_writes"]
    assert w_logical >= 1.5 * w_physical

    store.clear_summaries()
    fresh = TraceStore(store.root)
    results = run_queries(fresh, QUERIES)      # warm: classify + merge
    assert all(r.result.partial_hits > 0 for r in results)
    assert fresh.io_counts["shard_reads"] == 0
    r_logical = fresh.io_counts["partial_reads"]
    r_physical = fresh.io_counts["pack_reads"]
    assert r_logical >= 1.5 * r_physical
    # the consolidation is per-shard exact: one physical read serves
    # every lane of a shard
    assert r_physical == len(fresh.shard_indices())


# --- thread-safe io_counts --------------------------------------------------

def test_io_counts_thread_safe_under_concurrent_updates(tmp_path):
    """N threads hammering reads+writes on one TraceStore must never
    lose a counter increment (the plain-dict += race this pins)."""
    store = TraceStore(str(tmp_path / "s"))
    payload = {"version": np.asarray(4, np.int64),
               "fingerprint": np.asarray([1, 2, 3], np.int64),
               "bins": np.arange(4)}
    n_threads, n_iter = 8, 50

    def work(t):
        for i in range(n_iter):
            store.write_partial(t, f"{t:08x}{i % 4:08x}", payload)
            store.read_partial(t, f"{t:08x}{i % 4:08x}")

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert store.io_counts["partial_writes"] == n_threads * n_iter
    assert store.io_counts["partial_reads"] == n_threads * n_iter
