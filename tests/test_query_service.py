"""Query-serving front door tests: HTTP round-trip correctness against
direct ``run_queries``, deterministic admission batching (N submitted
requests drain into ONE fused plan), the per-request result-size budget
(HTTP 413), the byte-budgeted summary LRU — which must never evict a
key touched within the current tick — and the concurrency layer:
parallel-scan bit-identity, overlapping-tick in-flight dedup, the
dead-worker 503/tick_timeout contract, and the pack-byte-budget LRU."""

import json
import os
import shutil
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import (Query, SyntheticSpec, TraceStore,
                        generate_synthetic, run_generation, run_queries,
                        write_rank_db)
from repro.core.aggregation import ScanPool
from repro.core.tracestore import summary_filename
from repro.serve.query_service import (BudgetExceeded, QueryService,
                                       ServiceConfig)


@pytest.fixture(scope="module")
def base(tmp_path_factory):
    spec = SyntheticSpec(n_ranks=2, kernels_per_rank=2000,
                         memcpys_per_rank=300, duration_s=20.0, seed=7)
    ds = generate_synthetic(spec)
    root = tmp_path_factory.mktemp("svc_base")
    paths = []
    for tr in ds.traces:
        p = str(root / f"rank{tr.rank}.sqlite")
        write_rank_db(p, tr)
        paths.append(p)
    store_dir = str(root / "store")
    run_generation(paths, store_dir, n_ranks=2)
    return store_dir


@pytest.fixture
def store_dir(base, tmp_path):
    dst = str(tmp_path / "s")
    shutil.copytree(base, dst)
    return dst


def _post(port, specs, timeout=60):
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}/query",
        data=json.dumps(specs).encode(),
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def test_http_round_trip_matches_direct_run_queries(store_dir):
    """A served answer is the engine's answer: group counts/means from
    the HTTP JSON equal a direct ``run_queries`` on the same store, and
    the response carries the engine's provenance fields."""
    svc = QueryService(store_dir, ServiceConfig(tick_ms=5.0, port=0))
    svc.start(serve_http=True)
    try:
        status, body = _post(svc.cfg.port,
                             [{"metrics": ["k_stall"],
                               "group_by": "m_kind"}])
        assert status == 200
        r = body["results"][0]
        direct = run_queries(
            TraceStore(store_dir),
            [Query(metrics=("k_stall",), group_by="m_kind")])[0]
        g = direct.result.grouped
        cnt = g.count.sum(axis=0)
        tot = g.sum.sum(axis=0)
        for gi, gk in enumerate(
                np.asarray(direct.result.group_keys).ravel()):
            got = r["groups"][f"{float(gk):g}"]["k_stall"]
            assert got["count"] == int(cnt[gi, 0])
            np.testing.assert_allclose(got["mean"],
                                       tot[gi, 0] / cnt[gi, 0])
        assert r["n_samples"] == int(cnt.sum())
        for f in ("cache_hit", "recomputed_shards", "partial_hits",
                  "shards_pruned", "rows_scanned", "provenance"):
            assert f in r
        # second ask: pure summary hit through the shared store
        status, body = _post(svc.cfg.port,
                             [{"metrics": ["k_stall"],
                               "group_by": "m_kind"}])
        assert status == 200
        assert body["results"][0]["cache_hit"]
    finally:
        svc.stop()


def test_submitted_requests_drain_into_one_fused_plan(store_dir):
    """Deterministic batching (no worker thread): three requests with
    five queries total, submitted before one ``drain_once``, ride ONE
    fused plan — every response reports the full fused width and
    ``batched_fused``."""
    svc = QueryService(store_dir, ServiceConfig(tick_ms=1.0))
    pendings = [
        svc.submit([Query(metrics=("k_stall",), group_by="m_kind")]),
        svc.submit([Query(metrics=("m_duration",), ranks=(0,)),
                    Query(metrics=("m_bytes",), group_by="k_device")]),
        svc.submit([Query(metrics=("k_stall",), group_by="m_kind"),
                    Query(metrics=("k_stall",), anomaly_score="p99")]),
    ]
    served = svc.drain_once(block_s=0.0)
    assert served == 3
    for p in pendings:
        assert p.done.is_set() and p.error is None
        assert p.tick_info["fused_width"] == 5
        assert p.tick_info["batched_fused"] is True
        assert len(p.results) == len(p.queries)
    assert pendings[2].results[1]["anomalous_bins"] >= 0
    assert svc.stats()["max_fused_width"] == 5
    assert svc.drain_once(block_s=0.0) == 0        # queue drained


def test_over_budget_request_is_rejected_413(store_dir):
    """A pathological re-binning (1 us bins over the whole trace) blows
    the estimated result-cell budget at ADMISSION — BudgetExceeded from
    submit, HTTP 413 over the wire — without ever touching a shard."""
    svc = QueryService(store_dir, ServiceConfig(
        tick_ms=1.0, max_cells_per_request=100_000, port=0))
    with pytest.raises(BudgetExceeded):
        svc.submit([Query(metrics=("k_stall",), interval_ns=1_000)])
    svc.start(serve_http=True)
    try:
        status, body = _post(svc.cfg.port,
                             [{"metrics": ["k_stall"],
                               "interval_ns": 1000}])
        assert status == 413
        assert body["error"]["code"] == "budget_exceeded"
        assert "budget" in body["error"]["message"]
        assert QueryService(store_dir).store.io_counts["shard_reads"] == 0
    finally:
        svc.stop()


def test_lru_never_evicts_summary_read_within_same_tick(store_dir):
    """Byte budget of 1: eviction pressure is permanent, yet each tick's
    own summary keys survive that tick (a result can never be evicted
    between compute and read-back); the PREVIOUS tick's keys are the
    ones reclaimed."""
    svc = QueryService(store_dir, ServiceConfig(
        tick_ms=1.0, summary_budget_bytes=1))
    q_a = Query(metrics=("k_stall",), group_by="m_kind")
    q_b = Query(metrics=("m_duration",), group_by="m_kind")

    p = svc.submit([q_a])
    svc.drain_once(block_s=0.0)
    assert p.error is None and p.tick_info["evicted"] == 0
    keys_after_a = set(svc.store.summary_keys())
    assert len(keys_after_a) == 1                  # A survives its tick

    p = svc.submit([q_b])
    svc.drain_once(block_s=0.0)
    assert p.error is None and p.tick_info["evicted"] == 1
    keys_after_b = set(svc.store.summary_keys())
    assert len(keys_after_b) == 1                  # B survives, A gone
    assert keys_after_b != keys_after_a
    (key_b,) = keys_after_b
    assert os.path.exists(os.path.join(svc.store.root,
                                       summary_filename(key_b)))
    # evicting a summary is safe: A recomputes from partials, no rescan
    fresh = TraceStore(store_dir)
    res = run_queries(fresh, [q_a])[0]
    assert res.result.partial_hits > 0
    assert fresh.io_counts["shard_reads"] == 0


# --- concurrency: scan pool, pipelined ticks, pack LRU ----------------------

def test_scan_pool_results_bit_identical_to_serial(store_dir):
    """Cold fused scans through a 4-worker :class:`ScanPool` produce
    EXACTLY the serial path's tensors (array equality, not allclose):
    each shard partial is a pure function of its shard and the merge
    consumes them in fixed shard order, never completion order."""
    queries = [Query(metrics=("k_stall",), group_by="m_kind"),
               Query(metrics=("m_duration", "m_bytes"),
                     group_by="m_kind"),
               Query(metrics=("k_stall",), anomaly_score="p99")]
    store = TraceStore(store_dir)
    store.clear_summaries()
    store.clear_partials()
    serial = run_queries(store, queries)
    store.clear_summaries()
    store.clear_partials()
    with ScanPool(4) as pool:
        pooled = run_queries(store, queries, pool=pool)
        util = pool.utilization()
    assert util["workers"] == 4 and util["tasks"] > 0
    for a, b in zip(serial, pooled):
        assert np.array_equal(a.result.group_keys, b.result.group_keys)
        for name, sa in a.result.reduced.items():
            sb = b.result.reduced[name]
            for f in sa.fields:
                assert np.array_equal(getattr(sa, f), getattr(sb, f))


def test_overlapping_ticks_share_inflight_computation(store_dir,
                                                      monkeypatch):
    """Pipelined: a query admitted while an earlier tick is still
    computing the same canonical query BORROWS that tick's slot — one
    execution serves both, and the borrower's response says so
    (``inflight_hit`` provenance, ``inflight_hits`` stat)."""
    started, release = threading.Event(), threading.Event()
    orig = QueryService._exec_tick

    def stalling_exec(self, tick):
        if tick.owned:                 # owner tick: stall mid-flight
            started.set()
            release.wait(10)
        orig(self, tick)

    monkeypatch.setattr(QueryService, "_exec_tick", stalling_exec)
    svc = QueryService(store_dir, ServiceConfig(
        tick_ms=1.0, pipeline_depth=2, scan_workers=1))
    svc.start(serve_http=False)
    try:
        q = Query(metrics=("k_stall",), group_by="m_kind")
        pa = svc.submit([q])
        assert started.wait(5)
        pb = svc.submit([q])           # same canonical key, next tick
        time.sleep(0.2)                # let tick 2 admit and borrow
        release.set()
        assert pa.done.wait(10) and pb.done.wait(10)
        assert pa.error is None and pb.error is None
        assert pa.results[0].get("inflight_hit") is None
        assert pb.results[0]["inflight_hit"] is True
        assert pb.results[0]["groups"] == pa.results[0]["groups"]
        assert svc.stats()["inflight_hits"] == 1
    finally:
        release.set()
        svc.stop()


def test_dead_tick_worker_yields_503_tick_timeout(store_dir,
                                                  monkeypatch):
    """A tick worker killed mid-tick (its tick never fills slots, never
    commits) must surface as HTTP 503 with error code ``tick_timeout``
    within ``request_timeout_s`` — never a handler parked forever — and
    the service keeps serving fresh keys afterwards."""
    killed = threading.Event()
    orig = QueryService._pipeline_task

    def dying_task(self, tick):
        if not killed.is_set():
            killed.set()               # first tick: worker dies here —
            return                     # no slot fill, no commit
        orig(self, tick)

    monkeypatch.setattr(QueryService, "_pipeline_task", dying_task)
    svc = QueryService(store_dir, ServiceConfig(
        tick_ms=1.0, pipeline_depth=2, scan_workers=1,
        request_timeout_s=0.5, port=0))
    svc.start(serve_http=True)
    try:
        status, body = _post(svc.cfg.port,
                             [{"metrics": ["k_stall"],
                               "group_by": "m_kind"}], timeout=30)
        assert status == 503
        assert body["error"]["code"] == "tick_timeout"
        # the pipeline survived its dead worker: a different canonical
        # query rides a healthy tick
        status, body = _post(svc.cfg.port,
                             [{"metrics": ["m_bytes"],
                               "group_by": "k_device"}], timeout=30)
        assert status == 200
        assert body["results"][0]["n_samples"] > 0
    finally:
        svc.stop()


def test_pack_budget_evicts_only_committed_ticks_packs(store_dir):
    """``pack_budget_bytes=1`` is permanent pressure, yet a tick's packs
    are immune while it is in flight: the full-store tick keeps every
    pack through its own commit, and they are reclaimed by a later
    tick that only touches a shard subset. Evicted packs are derived
    data — the next cold ask recomputes and answers identically."""
    svc = QueryService(store_dir, ServiceConfig(
        tick_ms=1.0, pack_budget_bytes=1))
    q_full = Query(metrics=("k_stall",), group_by="m_kind")
    p = svc.submit([q_full])
    svc.drain_once(block_s=0.0)
    assert p.error is None
    first = p.results[0]
    # own-tick immunity: every pack this tick wrote survived its commit
    packs_after_full = set(svc.store.pack_sizes())
    assert packs_after_full
    assert svc.stats()["pack_evictions"] == 0

    # a time-windowed tick touches only early shards; everything else
    # is now fair game for the byte budget
    man = svc.man
    span = int(man.t_end - man.t_start)
    q_win = Query(metrics=("k_stall",),
                  time_window=(int(man.t_start),
                               int(man.t_start + span // 4)))
    p = svc.submit([q_win])
    svc.drain_once(block_s=0.0)
    assert p.error is None
    assert svc.stats()["pack_evictions"] > 0
    assert set(svc.store.pack_sizes()) < packs_after_full

    # packs are pure derived data: cold re-ask, identical answer
    svc.store.clear_summaries()
    p = svc.submit([q_full])
    svc.drain_once(block_s=0.0)
    assert p.error is None
    assert p.results[0]["groups"] == first["groups"]
    assert p.results[0]["n_samples"] == first["n_samples"]
