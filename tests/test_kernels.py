"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) ≡ ref.py oracle
≡ the numpy aggregation path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:           # degrade property sweeps to skips
    HAVE_HYPOTHESIS = False

from repro.core.aggregation import bin_samples
from repro.core.reducers import N_BUCKETS, QuantileSketch, bucket_of
from repro.core.sharding import ShardPlan
from repro.kernels import (binstats, binstats_ref, histbin, iqr_fences,
                           iqr_ref, rolling_ref, rolling_stats)


def _events(rng, n, total_ns):
    ts = rng.uniform(0, total_ns, n).astype(np.float32)
    vals = rng.normal(100, 30, n).astype(np.float32)
    return jnp.asarray(ts), jnp.asarray(vals)


@pytest.mark.parametrize("n,n_bins", [
    (100, 7), (1024, 128), (3000, 50), (4096, 256), (5, 3), (2048, 1),
])
def test_binstats_kernel_matches_ref(n, n_bins):
    rng = np.random.default_rng(n + n_bins)
    total = 1e9
    ts, vals = _events(rng, n, total)
    valid = jnp.asarray(rng.random(n) > 0.1)
    out_k = binstats(ts, vals, valid, total_ns=total, n_bins=n_bins,
                     use_kernel=True)
    out_r = binstats(ts, vals, valid, total_ns=total, n_bins=n_bins,
                     use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-2)


@pytest.mark.parametrize("ev_tile,bin_tile", [(256, 128), (1024, 256)])
def test_binstats_tile_shapes(ev_tile, bin_tile):
    rng = np.random.default_rng(0)
    ts, vals = _events(rng, 2000, 1e9)
    valid = jnp.ones(2000, bool)
    out_k = binstats(ts, vals, valid, total_ns=1e9, n_bins=100,
                     use_kernel=True, ev_tile=ev_tile, bin_tile=bin_tile)
    out_r = binstats(ts, vals, valid, total_ns=1e9, n_bins=100,
                     use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-2)


def test_binstats_matches_host_aggregation():
    """Kernel contract == the numpy BinStats path used by the pipeline."""
    rng = np.random.default_rng(1)
    n, n_bins, total = 4000, 64, 1e9
    ts, vals = _events(rng, n, total)
    valid = jnp.ones(n, bool)
    out = np.asarray(binstats(ts, vals, valid, total_ns=total,
                              n_bins=n_bins, use_kernel=True))
    plan = ShardPlan(0, int(total), n_bins)
    # identical float32 binning contract
    bins = np.clip((np.asarray(ts) * np.float32(n_bins / total)
                    ).astype(np.int32), 0, n_bins - 1)
    ref = bin_samples(np.asarray(plan.boundaries()[bins], np.int64),
                      np.asarray(vals, np.float64), plan)
    np.testing.assert_allclose(out[:, 0], ref.count, atol=0)
    np.testing.assert_allclose(out[:, 1], ref.sum, rtol=1e-4)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 600), n_bins=st.integers(1, 64),
           seed=st.integers(0, 99))
    def test_binstats_property_sweep(n, n_bins, seed):
        rng = np.random.default_rng(seed)
        ts, vals = _events(rng, n, 1e8)
        valid = jnp.asarray(rng.random(n) > 0.2)
        k = binstats(ts, vals, valid, total_ns=1e8, n_bins=n_bins,
                     use_kernel=True)
        r = binstats(ts, vals, valid, total_ns=1e8, n_bins=n_bins,
                     use_kernel=False)
        np.testing.assert_allclose(np.asarray(k), np.asarray(r),
                                   rtol=1e-5, atol=1e-2)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_binstats_property_sweep():
        pass


def test_binstats_multimetric_matches_single_runs():
    """A batched (M, N) pass returns, per metric, the same moments as M
    independent single-metric kernel calls (shared one-hot, one matmul)."""
    rng = np.random.default_rng(11)
    n, n_bins, total = 3000, 50, 1e9
    ts, v0 = _events(rng, n, total)
    v1 = jnp.asarray(rng.normal(5, 2, n).astype(np.float32))
    v2 = jnp.asarray(rng.uniform(0, 1e6, n).astype(np.float32))
    valid = jnp.asarray(rng.random(n) > 0.1)
    batch = jnp.stack([v0, v1, v2])
    mk = binstats(ts, batch, valid, total_ns=total, n_bins=n_bins,
                  use_kernel=True)
    mr = binstats(ts, batch, valid, total_ns=total, n_bins=n_bins,
                  use_kernel=False)
    assert mk.shape == (3, n_bins, 5)
    np.testing.assert_allclose(np.asarray(mk), np.asarray(mr),
                               rtol=1e-5, atol=1e-2)
    for j, v in enumerate((v0, v1, v2)):
        single = binstats(ts, v, valid, total_ns=total, n_bins=n_bins,
                          use_kernel=True)
        np.testing.assert_allclose(np.asarray(mk[j]), np.asarray(single),
                                   rtol=1e-5, atol=1e-2)
        # counts are metric-independent and exactly shared
        np.testing.assert_array_equal(np.asarray(mk[j][:, 0]),
                                      np.asarray(mk[0][:, 0]))


# --- histbin ---------------------------------------------------------------------

@pytest.mark.parametrize("n,n_bins", [
    (100, 7), (1024, 128), (3000, 50), (5, 3), (2048, 1),
])
def test_histbin_kernel_matches_ref(n, n_bins):
    """Pallas double-one-hot scatter-as-matmul ≡ segment_sum oracle,
    EXACTLY (both count integer events in float32)."""
    rng = np.random.default_rng(n + n_bins)
    total = 1e9
    ts, _ = _events(rng, n, total)
    vals = jnp.asarray(np.abs(rng.normal(5000, 3000, n)), jnp.float32)
    valid = jnp.asarray(rng.random(n) > 0.1)
    out_k = histbin(ts, vals, valid, total_ns=total, n_bins=n_bins,
                    use_kernel=True)
    out_r = histbin(ts, vals, valid, total_ns=total, n_bins=n_bins,
                    use_kernel=False)
    assert out_k.shape == (n_bins, N_BUCKETS)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    assert float(np.asarray(out_k).sum()) == float(np.asarray(valid).sum())


def test_histbin_multimetric_matches_single_runs():
    """A batched (M, N) pass returns, per metric, the same histogram as M
    independent single-metric kernel calls (shared bin one-hot)."""
    rng = np.random.default_rng(5)
    n, n_bins, total = 2000, 40, 1e9
    ts, _ = _events(rng, n, total)
    v0 = jnp.asarray(np.abs(rng.normal(1e4, 3e3, n)), jnp.float32)
    v1 = jnp.asarray(rng.uniform(1, 1e7, n).astype(np.float32))
    valid = jnp.asarray(rng.random(n) > 0.2)
    batch = jnp.stack([v0, v1])
    mk = histbin(ts, batch, valid, total_ns=total, n_bins=n_bins,
                 use_kernel=True)
    assert mk.shape == (2, n_bins, N_BUCKETS)
    for j, v in enumerate((v0, v1)):
        single = histbin(ts, v, valid, total_ns=total, n_bins=n_bins,
                         use_kernel=True)
        np.testing.assert_array_equal(np.asarray(mk[j]),
                                      np.asarray(single))


def test_histbin_feeds_quantile_sketch():
    """Kernel output drops into QuantileSketch and answers quantiles that
    match the host float64 sketch path on boundary-safe values."""
    rng = np.random.default_rng(9)
    n, n_bins, total = 4000, 16, 1e9
    ts = rng.uniform(0, total, n).astype(np.float32)
    vals = np.abs(rng.lognormal(8.0, 1.0, n)).astype(np.float32)
    valid = np.ones(n, bool)
    out = np.asarray(histbin(jnp.asarray(ts), jnp.asarray(vals),
                             jnp.asarray(valid), total_ns=total,
                             n_bins=n_bins, use_kernel=True))
    sk = QuantileSketch(counts=out.astype(np.float64))
    # host sketch over identical float32-binned rows
    host = np.zeros((n_bins, N_BUCKETS))
    bins = np.clip((ts * np.float32(n_bins / total)).astype(np.int32),
                   0, n_bins - 1)
    np.add.at(host, (bins, bucket_of(vals.astype(np.float64))), 1.0)
    hs = QuantileSketch(counts=host)
    occ = sk.total() > 0
    for q in (0.5, 0.95, 0.99):
        np.testing.assert_allclose(sk.quantile(q)[occ],
                                   hs.quantile(q)[occ], rtol=1e-6)


# --- iqr ------------------------------------------------------------------------

@pytest.mark.parametrize("n", [8, 100, 255, 1024])
def test_iqr_kernel_matches_ref(n):
    rng = np.random.default_rng(n)
    s = rng.normal(10, 2, n).astype(np.float32)
    s[rng.integers(0, n, 3)] *= 10
    occ = s != 0
    k = iqr_fences(jnp.asarray(s), jnp.asarray(occ), use_kernel=True)
    r = iqr_fences(jnp.asarray(s), jnp.asarray(occ), use_kernel=False)
    for key in ("q1", "q3", "hi_fence"):
        np.testing.assert_allclose(float(k[key]), float(r[key]),
                                   rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(k["flags"]),
                                  np.asarray(r["flags"]))


def test_iqr_kernel_sorted_output_is_sorted():
    rng = np.random.default_rng(0)
    s = rng.normal(0, 5, 200).astype(np.float32)
    k = iqr_fences(jnp.asarray(s), jnp.asarray(np.ones(200, bool)),
                   use_kernel=True)
    srt = np.asarray(k["sorted"])
    assert np.all(np.diff(srt) >= 0)


def test_iqr_matches_numpy_quartiles():
    rng = np.random.default_rng(5)
    s = np.abs(rng.normal(10, 3, 501)).astype(np.float32)
    k = iqr_fences(jnp.asarray(s), jnp.asarray(s != 0), use_kernel=True)
    q1, q3 = np.percentile(s, [25, 75])
    np.testing.assert_allclose(float(k["q1"]), q1, rtol=2e-2)
    np.testing.assert_allclose(float(k["q3"]), q3, rtol=2e-2)


# --- rolling ---------------------------------------------------------------------

@pytest.mark.parametrize("n,window", [(64, 8), (500, 32), (1000, 100),
                                      (100, 1)])
def test_rolling_kernel_matches_ref(n, window):
    rng = np.random.default_rng(n + window)
    x = rng.normal(0, 2, n).astype(np.float32)
    k = rolling_stats(jnp.asarray(x), window=window, use_kernel=True)
    r = rolling_stats(jnp.asarray(x), window=window, use_kernel=False)
    np.testing.assert_allclose(np.asarray(k), np.asarray(r),
                               rtol=1e-4, atol=1e-4)


def test_rolling_matches_numpy():
    rng = np.random.default_rng(2)
    n, w = 300, 16
    x = rng.normal(5, 3, n).astype(np.float32)
    out = np.asarray(rolling_stats(jnp.asarray(x), window=w,
                                   use_kernel=True))
    for i in (w - 1, n // 2, n - 1):
        seg = x[max(0, i - w + 1): i + 1]
        np.testing.assert_allclose(out[i, 0], seg.mean(), rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_allclose(out[i, 1], seg.std(), rtol=1e-3,
                                   atol=1e-3)


# --- ssd (fused SSD chunk scan) ----------------------------------------------------

@pytest.mark.parametrize("b,s,H,P,G,N,chunk", [
    (2, 37, 4, 8, 2, 16, 8),
    (1, 64, 2, 16, 1, 32, 16),
    (2, 16, 8, 8, 8, 8, 16),     # s < padded multiple, G == H
])
def test_ssd_kernel_matches_oracle_and_scan(b, s, H, P, G, N, chunk):
    from repro.kernels.ssd import ssd_fused
    from repro.models.ssm import ssd_scan
    rng = np.random.default_rng(b + s + H)
    xs = jnp.asarray(rng.normal(size=(b, s, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (b, s, H)), jnp.float32)
    A_log = jnp.asarray(rng.uniform(-1, 1, (H,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, G, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, G, N)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    yk, hk = ssd_fused(xs, dt, A_log, B, C, D, chunk=chunk,
                       use_kernel=True)
    yr, hr = ssd_fused(xs, dt, A_log, B, C, D, chunk=chunk,
                       use_kernel=False)
    y0, h0 = ssd_scan(xs, dt, A_log, B, C, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(h0),
                               rtol=1e-4, atol=1e-4)


def test_ssd_kernel_bf16_inputs():
    from repro.kernels.ssd import ssd_fused
    rng = np.random.default_rng(0)
    b, s, H, P, G, N = 1, 32, 2, 8, 1, 16
    xs = jnp.asarray(rng.normal(size=(b, s, H, P)), jnp.bfloat16)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (b, s, H)), jnp.float32)
    A_log = jnp.asarray(rng.uniform(-1, 1, (H,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, G, N)), jnp.bfloat16)
    C = jnp.asarray(rng.normal(size=(b, s, G, N)), jnp.bfloat16)
    D = jnp.ones((H,), jnp.float32)
    yk, hk = ssd_fused(xs, dt, A_log, B, C, D, chunk=16, use_kernel=True)
    yr, hr = ssd_fused(xs, dt, A_log, B, C, D, chunk=16, use_kernel=False)
    assert yk.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_ssm_block_pallas_path_matches_xla():
    import dataclasses as dc
    from repro.models.ssm import SSMConfig, ssm_init, ssm_forward
    rng = np.random.default_rng(0)
    cfg = SSMConfig(d_model=32, d_state=16, head_dim=8, n_groups=2,
                    chunk=8)
    params = ssm_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 20, 32)), jnp.float32)
    out_x, cache_x = ssm_forward(params, x, cfg)
    cfg_p = dc.replace(cfg, use_pallas=True)
    out_p, cache_p = ssm_forward(params, x, cfg_p)
    np.testing.assert_allclose(np.asarray(out_x), np.asarray(out_p),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cache_x["state"]),
                               np.asarray(cache_p["state"]),
                               rtol=1e-4, atol=1e-4)


# --- flashattn ---------------------------------------------------------------------

@pytest.mark.parametrize("s,causal,window,dtype", [
    (100, True, 0, jnp.float32),
    (64, True, 16, jnp.float32),
    (80, False, 0, jnp.float32),
    (96, True, 0, jnp.bfloat16),
])
def test_flash_attention_kernel_matches_refs(s, causal, window, dtype):
    from repro.kernels.flashattn import flash_attention
    from repro.models.attention import chunked_attention
    rng = np.random.default_rng(s)
    b, h, hd = 2, 3, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), dtype)
    ok = flash_attention(q, k, v, causal=causal, window=window,
                         q_tile=32, kv_tile=32, use_kernel=True)
    orf = flash_attention(q, k, v, causal=causal, window=window,
                          use_kernel=False)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(ok, np.float32),
                               np.asarray(orf, np.float32),
                               rtol=tol, atol=tol)
    if dtype == jnp.float32:
        oc = chunked_attention(q, k, v, causal=causal, window=window,
                               q_chunk=32, kv_chunk=32)
        np.testing.assert_allclose(np.asarray(ok), np.asarray(oc),
                                   rtol=2e-4, atol=2e-4)


def test_flash_attention_tile_invariance():
    from repro.kernels.flashattn import flash_attention
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 8)), jnp.float32)
    a = flash_attention(q, k, v, q_tile=16, kv_tile=16)
    b = flash_attention(q, k, v, q_tile=64, kv_tile=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)
