"""Serving-stack tests: batched greedy engine, cache round-trips,
telemetry instrumentation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.model import decode_step, init_params, prefill
from repro.serve import ServeConfig, ServeEngine


RNG = np.random.default_rng(3)


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "mamba2-370m",
                                  "deepseek-v2-236b", "hymba-1.5b"])
def test_engine_greedy_determinism(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(
        max_len=64, max_new_tokens=6, cache_dtype=jnp.float32))
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab, (2, 12)), jnp.int32)}
    a = eng.generate(batch)
    b = eng.generate(batch)
    assert a.shape == (2, 6)
    np.testing.assert_array_equal(a, b)


def test_engine_records_telemetry():
    cfg = get_smoke_config("stablelm-3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(
        max_len=48, max_new_tokens=4, cache_dtype=jnp.float32))
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab, (1, 8)), jnp.int32)}
    eng.generate(batch)
    durs = eng.telemetry.step_durations()
    assert len(durs) == 4            # 1 prefill + 3 decode
    kinds = {e.kind for e in eng.telemetry.steps}
    assert kinds == {1, 2}           # KIND_PREFILL, KIND_DECODE


def test_decode_continuation_matches_long_prefill():
    """prefill(N) + decode ≡ prefill(N+1) logits — engine-level contract
    for a model WITH meta tokens (index bookkeeping is the tricky bit)."""
    cfg = get_smoke_config("hymba-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 10)), jnp.int32)
    lg_full, _, _ = prefill(cfg, params, {"tokens": toks}, max_len=64,
                            cache_dtype=jnp.float32)
    lg, caches, idx = prefill(cfg, params, {"tokens": toks[:, :-1]},
                              max_len=64, cache_dtype=jnp.float32)
    lg2, _ = decode_step(cfg, params, toks[:, -1:], caches, idx)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(lg_full),
                               rtol=5e-3, atol=5e-3)
