"""TraceStore contract tests: atomic-write crash safety (a dying writer
never leaves a torn shard or a stray tmp file) and the summary cache
(hit / miss / invalidation keyed on plan × metrics × group_by × shard
fingerprint)."""

import os

import numpy as np
import pytest

from repro.core.aggregation import run_aggregation
from repro.core.sharding import ShardPlan
from repro.core.tracestore import (StoreManifest, TraceStore,
                                   shard_filename, summary_filename)


class _Exploding:
    """Array-like that detonates when np.savez materializes it."""

    def __array__(self, dtype=None):
        raise RuntimeError("simulated writer crash")


def _tmp_files(root):
    return [f for f in os.listdir(root) if f.endswith(".tmp")]


def _mini_store(root, n_shards=6, n_rows=400, seed=0):
    """Small synthetic store written directly (no SQLite round trip)."""
    rng = np.random.default_rng(seed)
    store = TraceStore(root)
    plan = ShardPlan(0, 60_000, n_shards)
    cols_all = {
        "k_start": rng.integers(0, 60_000, n_rows).astype(np.float64),
        "k_stall": rng.normal(100, 25, n_rows),
        "m_bytes": rng.integers(0, 1 << 20, n_rows).astype(np.float64),
        "m_kind": rng.choice([1.0, 2.0, 8.0], n_rows),
        "m_start": rng.integers(0, 60_000, n_rows).astype(np.float64),
        "joined": rng.integers(0, 2, n_rows).astype(np.float64),
        "k_device": rng.integers(0, 4, n_rows).astype(np.float64),
    }
    sid = plan.shard_of(cols_all["k_start"].astype(np.int64))
    for s in range(n_shards):
        m = sid == s
        store.write_shard(s, {k: v[m] for k, v in cols_all.items()})
    store.write_manifest(StoreManifest(
        t_start=0, t_end=60_000, n_shards=n_shards, n_ranks=2,
        partitioning="block", columns=sorted(cols_all),
        shard_owner=[0] * n_shards))
    return store, plan


# --- atomic writes ---------------------------------------------------------

def test_crashed_shard_write_leaves_no_tmp_and_keeps_old_data(tmp_path):
    store = TraceStore(str(tmp_path))
    good = {"k_start": np.arange(5.0), "k_stall": np.ones(5)}
    store.write_shard(3, good)
    with pytest.raises(RuntimeError, match="simulated writer crash"):
        store.write_shard(3, {"k_start": _Exploding()})
    assert _tmp_files(store.root) == []          # torn tmp cleaned up
    cols = store.read_shard(3)                   # old shard intact
    np.testing.assert_array_equal(cols["k_start"], good["k_start"])


def test_crashed_fresh_shard_write_leaves_nothing(tmp_path):
    store = TraceStore(str(tmp_path))
    with pytest.raises(RuntimeError):
        store.write_shard(0, {"x": _Exploding()})
    assert _tmp_files(store.root) == []
    assert not store.has_shard(0)
    assert not os.path.exists(os.path.join(store.root, shard_filename(0)))


def test_crashed_summary_write_leaves_no_tmp(tmp_path):
    store = TraceStore(str(tmp_path))
    with pytest.raises(RuntimeError):
        store.write_summary("deadbeefdeadbeef", {"x": _Exploding()})
    assert _tmp_files(store.root) == []
    assert store.read_summary("deadbeefdeadbeef") is None


# --- summary cache ---------------------------------------------------------

def test_summary_cache_hit_returns_identical_moments(tmp_path):
    store, plan = _mini_store(str(tmp_path))
    cold = run_aggregation(store, metrics=["k_stall", "m_bytes"],
                           group_by="m_kind")
    assert not cold.from_cache
    warm = run_aggregation(store, metrics=["k_stall", "m_bytes"],
                           group_by="m_kind")
    assert warm.from_cache
    for f in ("count", "sum", "sumsq", "min", "max"):
        np.testing.assert_array_equal(getattr(cold.grouped, f),
                                      getattr(warm.grouped, f))
    np.testing.assert_array_equal(cold.group_keys, warm.group_keys)
    assert warm.metrics == ["k_stall", "m_bytes"]
    assert warm.group_by == "m_kind"
    for k in cold.copy_kind_bytes:
        np.testing.assert_array_equal(cold.copy_kind_bytes[k],
                                      warm.copy_kind_bytes[k])


def test_summary_cache_misses_on_different_query(tmp_path):
    store, _ = _mini_store(str(tmp_path))
    run_aggregation(store, metrics=["k_stall"])
    assert len(store.summary_keys()) == 1
    # different metric set, group column, or binning -> distinct entries
    r2 = run_aggregation(store, metrics=["k_stall", "m_bytes"])
    r3 = run_aggregation(store, metrics=["k_stall"], group_by="k_device")
    r4 = run_aggregation(store, metrics=["k_stall"], interval_ns=5_000)
    assert not any(r.from_cache for r in (r2, r3, r4))
    assert len(store.summary_keys()) == 4


def test_summary_cache_invalidated_by_shard_rewrite(tmp_path):
    store, _ = _mini_store(str(tmp_path))
    warm_key = store.summary_key((0, 60_000, 6), ["k_stall"], None)
    first = run_aggregation(store, metrics=["k_stall"])
    assert store.has_summary(warm_key)
    cols = store.read_shard(2)
    cols["k_stall"] = cols["k_stall"] + 1e6
    store.write_shard(2, cols)                   # fingerprint changes
    again = run_aggregation(store, metrics=["k_stall"])
    assert not again.from_cache
    assert again.stats.sum.sum() > first.stats.sum.sum()


def test_clear_summaries_drops_only_cache_files(tmp_path):
    store, _ = _mini_store(str(tmp_path))
    run_aggregation(store, metrics=["k_stall"])
    key = store.summary_keys()[0]
    assert os.path.exists(os.path.join(store.root, summary_filename(key)))
    n = store.clear_summaries()
    assert n == 1 and store.summary_keys() == []
    assert store.shard_indices() == list(range(6))  # shards untouched


def test_use_cache_false_never_writes(tmp_path):
    store, _ = _mini_store(str(tmp_path))
    run_aggregation(store, metrics=["k_stall"], use_cache=False)
    assert store.summary_keys() == []
