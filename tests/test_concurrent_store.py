"""Concurrency stress tests for the pipelined serving path: overlapping
ticks against a static store must be bit-identical to a serialized
replay, and overlapping ticks racing shard rewrites plus GC must stay
torn-free and answer-consistent (rewrites carry identical content, so
every response — before, during, after a rewrite — must equal the quiet
baseline; only the cache provenance may differ).
"""

import threading
import time

import pytest

from repro.core import (Query, SyntheticSpec, generate_synthetic,
                        run_generation, write_rank_db)
from repro.serve.query_service import QueryService, ServiceConfig

MIX = [
    {"metrics": ["k_stall"], "group_by": "m_kind"},
    {"metrics": ["m_duration", "m_bytes"], "group_by": "m_kind"},
    {"metrics": ["k_stall"], "reducers": ["moments", "quantile"],
     "anomaly_score": "p99"},
    {"metrics": ["m_bytes"], "group_by": "k_device"},
    {"metrics": ["k_stall", "m_duration"], "ranks": [0]},
    {"metrics": ["m_duration"], "transfer_kinds": [1, 2]},
]


@pytest.fixture(scope="module")
def store_dir(tmp_path_factory):
    spec = SyntheticSpec(n_ranks=2, kernels_per_rank=1200,
                         memcpys_per_rank=200, duration_s=12.0, seed=11)
    ds = generate_synthetic(spec)
    root = tmp_path_factory.mktemp("stress")
    paths = []
    for tr in ds.traces:
        p = str(root / f"rank{tr.rank}.sqlite")
        write_rank_db(p, tr)
        paths.append(p)
    out = str(root / "store")
    run_generation(paths, out, n_ranks=2)
    return out


def _strip(rendered):
    """The deterministic part of a rendered response — drop execution
    provenance (cache_hit, recomputed counts, inflight_hit), keep the
    numbers a client acts on."""
    return {"groups": rendered["groups"],
            "n_samples": rendered["n_samples"],
            "n_bins": rendered["n_bins"]}


def _serialized_reference(store_dir):
    """One quiet depth-1 pass over MIX: the replay every concurrent
    answer must match bit-for-bit (rendered floats compare exactly —
    both sides run the same deterministic merge)."""
    svc = QueryService(store_dir, ServiceConfig(tick_ms=1.0))
    ref = []
    for spec in MIX:
        p = svc.submit([Query.from_spec(spec)])
        svc.drain_once(block_s=0.0)
        assert p.error is None
        ref.append(_strip(p.results[0]))
    return ref


def test_pipelined_ticks_bit_identical_to_serialized_replay(store_dir):
    """Static store, depth-4 service, 6 client threads hammering the
    mixed workload with overlapping ticks: every response equals the
    serialized depth-1 replay exactly."""
    ref = _serialized_reference(store_dir)
    svc = QueryService(store_dir, ServiceConfig(
        tick_ms=2.0, pipeline_depth=4, scan_workers=2))
    svc.start(serve_http=False)
    problems = []

    def client(t):
        for i in range(8):
            j = (t + i) % len(MIX)
            p = svc.submit([Query.from_spec(MIX[j])])
            if not p.done.wait(60):
                problems.append(f"client {t}: request {i} timed out")
                return
            if p.error is not None:
                problems.append(f"client {t}: {p.error}")
                return
            if _strip(p.results[0]) != ref[j]:
                problems.append(
                    f"client {t}: spec {j} diverged from replay")

    try:
        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        svc.stop()
    assert not problems, problems
    assert svc.stats()["ticks"] > 1


def test_overlapping_ticks_survive_rewrites_and_gc(store_dir):
    """Overlapping ticks x shard rewrites x GC, all through one store:
    rewrites re-dirty fingerprints without changing content, so every
    concurrent answer must still equal the quiet baseline; afterwards
    no pack is torn (every pack parses, every surviving entry is
    readable) and the io tallies stayed consistent."""
    ref = _serialized_reference(store_dir)
    svc = QueryService(store_dir, ServiceConfig(
        tick_ms=2.0, pipeline_depth=4, scan_workers=2))
    svc.start(serve_http=False)
    stop = threading.Event()
    problems = []

    def querier(t):
        for i in range(10):
            j = (t + i) % len(MIX)
            p = svc.submit([Query.from_spec(MIX[j])])
            if not p.done.wait(60):
                problems.append(f"querier {t}: request {i} timed out")
                return
            if p.error is not None:
                problems.append(f"querier {t}: {p.error}")
                return
            if _strip(p.results[0]) != ref[j]:
                problems.append(
                    f"querier {t}: spec {j} diverged mid-mutation")

    def rewriter():
        idxs = svc.store.shard_indices()[:4]
        while not stop.is_set():
            for idx in idxs:
                try:
                    svc.store.write_shard(idx, svc.store.read_shard(idx))
                except Exception as e:   # noqa: BLE001 — fail the test
                    problems.append(f"rewriter: {type(e).__name__}: {e}")
                    return
                time.sleep(0.01)

    def collector():
        while not stop.is_set():
            try:
                svc.store.gc_stale()
            except Exception as e:       # noqa: BLE001 — fail the test
                problems.append(f"gc: {type(e).__name__}: {e}")
                return
            time.sleep(0.02)

    try:
        queriers = [threading.Thread(target=querier, args=(t,))
                    for t in range(4)]
        noise = [threading.Thread(target=rewriter),
                 threading.Thread(target=collector)]
        for t in queriers + noise:
            t.start()
        for t in queriers:
            t.join()
        stop.set()
        for t in noise:
            t.join()
    finally:
        stop.set()
        svc.stop()
    assert not problems, problems

    store = svc.store
    # no torn packs: every pack on disk parses, every surviving logical
    # entry is readable end-to-end
    for idx in store.pack_sizes():
        hit = store._load_pack(idx, want_raw=False)
        assert hit is None or hit[1] is not None, f"pack {idx} corrupt"
    for name in store.partial_names():
        parts = name[len("partial_"):-len(".npy")].split("_", 1)
        assert store.read_partial(int(parts[0]), parts[1]) is not None
    # io tallies stayed consistent under the storm: physical pack
    # writes never exceed the logical partial writes they batch, and
    # reads/writes both actually happened
    io = store.io_counts
    assert 0 < io["pack_writes"] <= io["partial_writes"]
    assert io["shard_reads"] > 0 and io["summary_reads"] > 0
