"""Incremental analysis engine tests: append-then-delta bit-identity with
a cold full aggregation, dirty-shard invalidation verified through the
store's IO counters, work-queue scheduler equality on skewed shards, and
crash-safety of the partial-cache atomic writes."""

import dataclasses
import os

import numpy as np
import pytest

from repro.core import (GenerationConfig, PipelineConfig, SyntheticSpec,
                        TraceStore, VariabilityPipeline, append_rank_db,
                        generate_synthetic, recovered, run_aggregation,
                        run_append, run_generation, trace_remainder,
                        truncate_trace, write_rank_db)
from repro.core.sharding import ShardPlan
from repro.core.tracestore import (StoreManifest, pack_filename,
                                   partial_filename)

METRICS = ["k_stall", "m_duration"]
SUITE = ("moments", "quantile")
_NS = 1_000_000_000
STAT_FIELDS = ("count", "sum", "sumsq", "min", "max")


@pytest.fixture(scope="module")
def growing_trace(tmp_path_factory):
    """A growing profiler run: DB snapshots at 30 s, the full 40 s trace
    arriving later at the SAME paths (profilers append in time order)."""
    spec = SyntheticSpec(n_ranks=2, kernels_per_rank=4000,
                         memcpys_per_rank=600, duration_s=40.0,
                         n_anomaly_windows=2, seed=7)
    ds = generate_synthetic(spec)
    t0 = int(ds.traces[0].kernels.start.min())
    cutoff = (t0 // _NS) * _NS + 30 * _NS        # interval-aligned
    dbs = tmp_path_factory.mktemp("growing_dbs")
    paths = [str(dbs / f"rank{tr.rank}.sqlite") for tr in ds.traces]
    return ds, paths, cutoff


def _write_snapshot(ds, paths, cutoff):
    for tr, p in zip(ds.traces, paths):
        write_rank_db(p, truncate_trace(tr, cutoff))


def _grow_dbs(ds, paths, cutoff):
    """Profiler growth model: APPEND the remaining events to the same DB
    files (fresh larger rowids — what the ingest watermark keys on)."""
    for tr, p in zip(ds.traces, paths):
        append_rank_db(p, trace_remainder(tr, cutoff))


def _base_store(ds, paths, cutoff, out_dir):
    _write_snapshot(ds, paths, cutoff)
    run_generation(paths, out_dir, n_ranks=2)
    return TraceStore(out_dir)


def _assert_results_equal(a, b):
    for f in STAT_FIELDS:
        np.testing.assert_array_equal(getattr(a.grouped, f),
                                      getattr(b.grouped, f))
    np.testing.assert_array_equal(a.group_keys, b.group_keys)
    if "quantile" in a.reduced:
        np.testing.assert_array_equal(a.reduced["quantile"].counts,
                                      b.reduced["quantile"].counts)
    assert set(a.copy_kind_bytes) == set(b.copy_kind_bytes)
    for k in a.copy_kind_bytes:
        np.testing.assert_array_equal(a.copy_kind_bytes[k],
                                      b.copy_kind_bytes[k])


# --- shard plan: boundary precision + append re-derivation ------------------
# (here rather than test_sharding_plan.py so they run without hypothesis)

def test_shard_of_exact_at_epoch_scale_boundaries():
    """Regression: epoch-scale int64 ns (~1.7e18) round to multiples of
    256 in float64, so converting the ABSOLUTE timestamp before
    subtracting t_start mis-binned events within ~256 ns of a shard
    boundary. The offset must be taken in int64 first."""
    t0 = 1_700_000_000_000_000_000
    plan = ShardPlan.from_interval(t0, t0 + 10 * _NS, _NS)
    edges = plan.boundaries()
    # probe every boundary +/- a few ns — exact binning required
    deltas = np.asarray([-3, -2, -1, 0, 1, 2, 3], np.int64)
    for b in range(1, plan.n_shards):
        ts = edges[b] + deltas
        sid = plan.shard_of(ts)
        expect = np.where(deltas < 0, b - 1, b)
        np.testing.assert_array_equal(sid, expect)
    # float64-typed input (shard columns are float64) bins identically
    # wherever the value itself is float64-representable
    reps = (edges[3] + deltas)[np.asarray(
        [int(float(v)) == int(v) for v in edges[3] + deltas])]
    np.testing.assert_array_equal(
        plan.shard_of(reps.astype(np.float64)), plan.shard_of(reps))


def test_extended_to_preserves_boundary_prefix():
    t0 = 1_700_000_000_000_000_000
    plan = ShardPlan.from_interval(t0, t0 + 7 * _NS, _NS)
    ext = plan.extended_to(t0 + 9 * _NS + 5)
    assert ext.t_start == plan.t_start
    assert ext.n_shards == 10                      # ceil to interval
    np.testing.assert_array_equal(ext.boundaries()[:plan.n_shards + 1],
                                  plan.boundaries())
    assert plan.extended_to(plan.t_end) is plan    # no-op within range
    ragged = ShardPlan(0, 10, 3)                   # non-integral width
    with pytest.raises(ValueError):
        ragged.extended_to(100)


# --- append-mode ingest -----------------------------------------------------

def test_append_extends_plan_without_moving_boundaries(growing_trace,
                                                       tmp_path):
    ds, paths, cutoff = growing_trace
    store = _base_store(ds, paths, cutoff, str(tmp_path / "s"))
    man0 = store.read_manifest()
    old_edges = ShardPlan(man0.t_start, man0.t_end,
                          man0.n_shards).boundaries()

    _grow_dbs(ds, paths, cutoff)                 # DBs grow in place
    rep = run_append(paths, store.root)
    man1 = store.read_manifest()
    assert rep.n_new_shards > 0
    assert man1.n_shards == man0.n_shards + rep.n_new_shards
    assert man1.t_start == man0.t_start and man1.t_end > man0.t_end
    new_edges = ShardPlan(man1.t_start, man1.t_end,
                          man1.n_shards).boundaries()
    np.testing.assert_array_equal(new_edges[:len(old_edges)], old_edges)
    # every new shard index has a file; owners extended, prefix untouched
    assert store.shard_indices() == list(range(man1.n_shards))
    assert man1.shard_owner[:man0.n_shards] == man0.shard_owner
    assert rep.appended_rows > 0
    # only the boundary shard may be dirtied (events spanning the
    # snapshot cutoff flush late); everything else is new shards
    assert set(rep.dirty_shards) <= {man0.n_shards - 1}


def test_append_then_delta_equals_cold_full_bit_identical(growing_trace,
                                                          tmp_path):
    """The acceptance criterion: after append(), aggregate() merges cached
    partials with the dirty/new rescan and matches a from-scratch cold
    aggregation of the same store bit for bit (moments, quantile sketch,
    transfer-kind bytes)."""
    ds, paths, cutoff = growing_trace
    store = _base_store(ds, paths, cutoff, str(tmp_path / "s"))
    base = run_aggregation(store, metrics=METRICS, group_by="m_kind",
                           reducers=SUITE)
    assert not base.from_cache

    _grow_dbs(ds, paths, cutoff)
    run_append(paths, store.root)
    delta = run_aggregation(TraceStore(store.root), metrics=METRICS,
                            group_by="m_kind", reducers=SUITE)
    assert not delta.from_cache
    assert delta.partial_hits > 0

    cold_store = TraceStore(store.root)
    cold_store.clear_summaries()
    cold_store.clear_partials()
    cold = run_aggregation(cold_store, metrics=METRICS, group_by="m_kind",
                           reducers=SUITE)
    assert cold.partial_hits == 0
    assert len(cold.recomputed_shards) > len(delta.recomputed_shards)
    _assert_results_equal(delta, cold)


def test_new_rank_db_dirties_existing_shards(growing_trace, tmp_path):
    """A late-arriving profiling rank whose events lie inside the covered
    range must extend the affected shard files and mark exactly those
    dirty for the next delta."""
    ds, paths, cutoff = growing_trace
    store = _base_store(ds, paths, cutoff, str(tmp_path / "s"))
    run_aggregation(store, metrics=METRICS)
    man0 = store.read_manifest()

    spec = dataclasses.replace(ds.spec, n_ranks=1, seed=99,
                               kernels_per_rank=500, memcpys_per_rank=80)
    late = generate_synthetic(spec)
    late_path = str(tmp_path / "late_rank.sqlite")
    write_rank_db(late_path, truncate_trace(late.traces[0], cutoff))
    rep = run_append([late_path], store.root)
    assert rep.n_new_shards == 0
    assert len(rep.dirty_shards) > 0

    fresh = TraceStore(store.root)
    delta = run_aggregation(fresh, metrics=METRICS)
    assert delta.recomputed_shards == rep.dirty_shards
    assert fresh.io_counts["shard_reads"] == len(rep.dirty_shards)
    assert delta.stats.count.sum() > man0.n_shards  # late rows included


def test_backfill_into_covered_range_is_ingested(growing_trace, tmp_path):
    """Regression (review finding): rows appended to a KNOWN DB whose
    timestamps fall inside the already-covered time range (late profiler
    flushes below the old plan end) must be ingested via the rowid
    watermark — the old start-time query silently dropped them."""
    ds, paths, cutoff = growing_trace
    store = _base_store(ds, paths, cutoff, str(tmp_path / "s"))
    first = run_aggregation(store, metrics=METRICS)
    # late flush: 50 events strictly INSIDE the covered range
    late = generate_synthetic(dataclasses.replace(
        ds.spec, n_ranks=1, seed=41, kernels_per_rank=50,
        memcpys_per_rank=10, duration_s=20.0))
    append_rank_db(paths[0], late.traces[0])
    rep = run_append(paths, store.root)
    assert rep.n_new_shards == 0
    assert rep.appended_rows >= 50
    assert len(rep.dirty_shards) > 0
    again = run_aggregation(TraceStore(store.root), metrics=METRICS)
    assert again.stats.count.sum() == first.stats.count.sum() + \
        rep.appended_rows


def test_rowid_bounded_read_excludes_mid_read_appends(growing_trace,
                                                      tmp_path):
    """The live-writer contract: a read bounded by ``max_rowids`` must
    not see rows appended after the watermark snapshot — they belong to
    the NEXT append, never skipped, never double-ingested."""
    from repro.core.events import read_rank_db, table_rowid_hi

    ds, paths, cutoff = growing_trace
    _write_snapshot(ds, paths, cutoff)
    wm = table_rowid_hi(paths[0])
    n_before = len(read_rank_db(paths[0], rank=0).kernels)
    _grow_dbs(ds, paths, cutoff)                 # "mid-read" growth
    bounded = read_rank_db(paths[0], rank=0, max_rowids=wm)
    assert len(bounded.kernels) == n_before      # growth invisible
    tail = read_rank_db(paths[0], rank=0, min_rowids=wm)
    assert len(tail.kernels) == len(
        read_rank_db(paths[0], rank=0).kernels) - n_before


def test_append_rejects_db_with_events_before_t_start(growing_trace,
                                                      tmp_path):
    """A late DB whose trace starts BEFORE the store's t_start would
    have its early events clipped into shard 0 — rejected loudly since
    the plan only extends forward."""
    ds, paths, cutoff = growing_trace
    store = _base_store(ds, paths, cutoff, str(tmp_path / "s"))
    man = store.read_manifest()
    early = generate_synthetic(dataclasses.replace(
        ds.spec, n_ranks=1, seed=13, kernels_per_rank=100,
        memcpys_per_rank=10, duration_s=5.0))
    tr = early.traces[0]
    tr.kernels.start -= 10 * _NS                 # pre-t_start events
    tr.kernels.end -= 10 * _NS
    early_path = str(tmp_path / "early_rank.sqlite")
    write_rank_db(early_path, tr)
    assert int(tr.kernels.start.min()) < man.t_start
    with pytest.raises(ValueError, match="t_start"):
        run_append([early_path], store.root)


def test_interrupted_append_is_refused_not_double_ingested(growing_trace,
                                                           tmp_path):
    """Crash safety across the multi-file append sequence: a leftover
    intent journal means shards may hold rows whose watermark never
    committed — a blind retry would ingest them twice, so run_append
    must refuse loudly. A completed append leaves no journal behind."""
    ds, paths, cutoff = growing_trace
    store = _base_store(ds, paths, cutoff, str(tmp_path / "s"))
    intent = os.path.join(store.root, "append_intent.json")

    _grow_dbs(ds, paths, cutoff)
    run_append(paths, store.root)
    assert not os.path.exists(intent)            # committed: journal gone

    with open(intent, "w") as f:                 # simulate a mid-append
        f.write("{}")                            # crash's leftover
    with pytest.raises(ValueError, match="interrupted"):
        run_append(paths, store.root)


def test_append_rejects_pre_watermark_store(growing_trace, tmp_path):
    """A store whose manifest predates ingest watermarks must be refused
    loudly — appending to it would re-ingest or drop rows silently."""
    ds, paths, cutoff = growing_trace
    store = _base_store(ds, paths, cutoff, str(tmp_path / "s"))
    man = store.read_manifest()
    man.extra.pop("db_rowid_hi")
    store.write_manifest(man)
    with pytest.raises(ValueError, match="watermark"):
        run_append(paths, store.root)


def test_append_without_new_data_keeps_summary_warm(growing_trace,
                                                    tmp_path):
    ds, paths, cutoff = growing_trace
    store = _base_store(ds, paths, cutoff, str(tmp_path / "s"))
    run_aggregation(store, metrics=METRICS)
    rep = run_append(paths, store.root)          # nothing new arrived
    assert rep.n_new_shards == 0 and rep.appended_rows == 0
    again = run_aggregation(TraceStore(store.root), metrics=METRICS)
    assert again.from_cache                       # summary survived the GC


def test_pipeline_append_refences_anomalies(growing_trace, tmp_path):
    """The automated-workflow loop end to end: run() on the snapshot,
    append() after the trace grows, and the refreshed fences recover the
    injected anomaly windows — with only dirty/new shards rescanned."""
    ds, paths, cutoff = growing_trace
    _write_snapshot(ds, paths, cutoff)
    cfg = PipelineConfig(n_ranks=2, backend="serial",
                         generation=GenerationConfig())
    pipe = VariabilityPipeline(cfg)
    work = str(tmp_path / "store")
    pipe.run(paths, work)

    _grow_dbs(ds, paths, cutoff)
    res = pipe.append(paths, work)
    assert res.generation.n_new_shards > 0
    assert not res.aggregation.from_cache
    assert res.aggregation.partial_hits > 0
    frac = recovered(ds.anomaly_windows, res.anomaly_windows,
                     tol_ns=_NS)
    assert frac == 1.0


def test_rebinned_delta_equals_rebinned_cold_after_append(growing_trace,
                                                          tmp_path):
    """The subtle reuse case: partials cached under a FINER aggregation
    interval, then an append extends the plan. Clean partials are reused
    across the extension (same origin + width ⇒ boundary prefix) unless
    their transfer-kind bins could have clipped at the old plan end —
    the delta must still match a cold rebinned run bit for bit."""
    ds, paths, cutoff = growing_trace
    store = _base_store(ds, paths, cutoff, str(tmp_path / "s"))
    half = 500_000_000
    run_aggregation(store, metrics=METRICS, group_by="m_kind",
                    interval_ns=half)
    _grow_dbs(ds, paths, cutoff)
    run_append(paths, store.root)

    delta = run_aggregation(TraceStore(store.root), metrics=METRICS,
                            group_by="m_kind", interval_ns=half)
    assert delta.partial_hits > 0
    cold_store = TraceStore(store.root)
    cold_store.clear_summaries()
    cold_store.clear_partials()
    cold = run_aggregation(cold_store, metrics=METRICS, group_by="m_kind",
                           interval_ns=half)
    _assert_results_equal(delta, cold)


def _single_kernel_trace(rank, starts, durations_ns, m_starts, m_bytes):
    """Hand-built RankTrace (device 0 throughout) for boundary tests."""
    from repro.core import EventTable, RankTrace
    from repro.core.events import COPY_H2D, GpuInfo
    starts = np.asarray(starts, np.int64)
    nk = len(starts)
    kernels = EventTable(
        start=starts, end=starts + np.asarray(durations_ns, np.int64),
        device=np.zeros(nk, np.int32), stream=np.zeros(nk, np.int32),
        memory_stall=np.full(nk, 100.0, np.float32),
        bytes=np.zeros(nk, np.int64), copy_kind=np.zeros(nk, np.int32),
        name_id=np.zeros(nk, np.int32), kind=np.zeros(nk, np.int32))
    m_starts = np.asarray(m_starts, np.int64)
    nm = len(m_starts)
    memcpys = EventTable(
        start=m_starts, end=m_starts + 1000,
        device=np.zeros(nm, np.int32), stream=np.zeros(nm, np.int32),
        memory_stall=np.zeros(nm, np.float32),
        bytes=np.asarray(m_bytes, np.int64),
        copy_kind=np.full(nm, COPY_H2D, np.int32),
        name_id=np.zeros(nm, np.int32), kind=np.ones(nm, np.int32))
    gpus = [GpuInfo(id=0, name="A100", bandwidth=1, memory=1, sm_count=1)]
    return RankTrace(rank=rank, kernels=kernels, memcpys=memcpys,
                     gpus=gpus)


def test_append_joins_memcpys_across_batch_boundary(tmp_path):
    """Regression (ROADMAP): a kernel appended in batch 2 whose join
    window reaches back over the ingest boundary must join memcpys
    ingested by batch 1 — the old query only saw memcpys fetched by the
    SAME append read, so such cross-batch matches were silently dropped.
    The appended store must match a from-scratch generation of the full
    DB for that kernel's joined rows."""
    t0 = 1_700_000_000_000_000_000
    window = 1_000_000                      # GenerationConfig default
    # batch 1: kernels spanning 4 intervals + one memcpy at t0 + 3.5 s
    m_start = t0 + 3 * _NS + _NS // 2
    base = _single_kernel_trace(
        0, starts=[t0 + i * _NS for i in range(4)],
        durations_ns=[10_000] * 4, m_starts=[m_start], m_bytes=[4096])
    db = str(tmp_path / "rank0.sqlite")
    write_rank_db(db, base)
    out = str(tmp_path / "store")
    run_generation([db], out, n_ranks=1)

    # batch 2: ONE kernel within the join window of batch 1's memcpy
    k_new = m_start + window // 2
    tail = _single_kernel_trace(0, starts=[k_new],
                                durations_ns=[10_000], m_starts=[],
                                m_bytes=[])
    append_rank_db(db, tail)
    rep = run_append([db], out)
    assert rep.appended_rows >= 1

    store = TraceStore(out)
    man = store.read_manifest()
    plan = ShardPlan(man.t_start, man.t_end, man.n_shards)
    cols = store.read_shard(int(plan.shard_of(np.asarray([k_new]))[0]))
    row = cols["k_start"] == float(k_new)
    assert row.sum() == 1                   # no duplicate joined rows
    assert cols["joined"][row] == 1.0       # cross-batch match found
    assert cols["m_bytes"][row] == 4096.0

    # the appended store's joined-row count equals a from-scratch build
    full = _single_kernel_trace(
        0, starts=[t0 + i * _NS for i in range(4)] + [k_new],
        durations_ns=[10_000] * 5, m_starts=[m_start], m_bytes=[4096])
    db2 = str(tmp_path / "rank0_full.sqlite")
    write_rank_db(db2, full)
    out2 = str(tmp_path / "store_scratch")
    run_generation([db2], out2, n_ranks=1)
    a = run_aggregation(TraceStore(out), metrics=["k_stall"])
    b = run_aggregation(TraceStore(out2), metrics=["k_stall"])
    np.testing.assert_array_equal(a.stats.count, b.stats.count)
    for k in b.copy_kind_bytes:
        np.testing.assert_array_equal(a.copy_kind_bytes[k],
                                      b.copy_kind_bytes[k])


# --- dirty-shard invalidation (read counters) -------------------------------

def test_shard_rewrite_recomputes_only_touched_partial(growing_trace,
                                                       tmp_path):
    ds, paths, cutoff = growing_trace
    store = _base_store(ds, paths, cutoff, str(tmp_path / "s"))
    first = run_aggregation(store, metrics=METRICS, group_by="m_kind")
    n = len(first.recomputed_shards)
    assert first.partial_hits == 0 and n > 0

    cols = store.read_shard(2)
    cols["k_stall"] = cols["k_stall"] + 1e6
    store.write_shard(2, cols)                   # invalidates shard 2 only

    fresh = TraceStore(store.root)
    again = run_aggregation(fresh, metrics=METRICS, group_by="m_kind")
    assert not again.from_cache
    assert again.recomputed_shards == [2]
    assert again.partial_hits == n - 1
    assert fresh.io_counts["shard_reads"] == 1   # ONLY the dirty shard
    assert fresh.io_counts["partial_reads"] == n - 1
    assert again.stats.sum.sum() > first.stats.sum.sum()


def test_use_cache_false_ignores_and_writes_no_partials(growing_trace,
                                                        tmp_path):
    ds, paths, cutoff = growing_trace
    store = _base_store(ds, paths, cutoff, str(tmp_path / "s"))
    run_aggregation(store, metrics=METRICS, use_cache=False)
    assert store.partial_names() == []
    assert store.summary_keys() == []


# --- work-stealing scheduler ------------------------------------------------

def _skewed_store(root, n_shards=12, seed=0):
    """Direct-written store with heavy row-count skew (anomaly-burst
    shape): two shards carry ~100x the rows of the rest."""
    rng = np.random.default_rng(seed)
    store = TraceStore(root)
    plan = ShardPlan(0, n_shards * 10_000, n_shards)
    for s in range(n_shards):
        lo, hi = plan.shard_bounds(s)
        n = 20_000 if s in (3, 7) else 200
        cols = {
            "k_start": rng.integers(lo, hi, n).astype(np.float64),
            "k_stall": rng.normal(100, 25, n),
            "m_duration": rng.lognormal(8, 1, n),
            "m_bytes": rng.integers(0, 1 << 20, n).astype(np.float64),
            "m_kind": rng.choice([1.0, 2.0, 8.0], n),
            "m_start": rng.integers(lo, hi, n).astype(np.float64),
            "joined": rng.integers(0, 2, n).astype(np.float64),
            "k_device": rng.integers(0, 4, n).astype(np.float64),
        }
        store.write_shard(s, cols)
    store.write_manifest(StoreManifest(
        t_start=0, t_end=plan.t_end, n_shards=n_shards, n_ranks=3,
        partitioning="block", columns=[], shard_owner=[0] * n_shards))
    return store


def test_workqueue_process_backend_equals_serial_on_skew(tmp_path):
    """The chunked imap_unordered queue must produce bit-identical
    results to the serial backend regardless of completion order, with
    straggler shards 100x the size of their neighbours."""
    store = _skewed_store(str(tmp_path / "skew"))
    results = {}
    for backend in ("serial", "process"):
        cfg = PipelineConfig(n_ranks=3, backend=backend, metrics=METRICS,
                             group_by="m_kind", reducers=SUITE,
                             use_summary_cache=False)
        results[backend] = VariabilityPipeline(cfg).aggregate(store.root)
    _assert_results_equal(results["serial"], results["process"])


def test_workqueue_workers_populate_partial_cache(tmp_path):
    """With the cache on, pool workers persist the partials they compute;
    a follow-up serial delta must find every shard clean."""
    store = _skewed_store(str(tmp_path / "skew2"))
    cfg = PipelineConfig(n_ranks=3, backend="process", metrics=METRICS,
                         group_by="m_kind")
    VariabilityPipeline(cfg).aggregate(store.root)
    assert len(store.partial_names()) == 12
    store.clear_summaries()                      # force a re-merge
    fresh = TraceStore(store.root)
    res = run_aggregation(fresh, n_ranks=3, metrics=METRICS,
                          group_by="m_kind")
    assert res.partial_hits == 12
    assert res.recomputed_shards == []
    assert fresh.io_counts["shard_reads"] == 0


# --- crash safety -----------------------------------------------------------

class _Exploding:
    def __array__(self, dtype=None):
        raise RuntimeError("simulated writer crash")


def test_partial_write_crash_leaves_no_tmp_or_torn_file(tmp_path):
    store = TraceStore(str(tmp_path))
    good = {"version": np.asarray(3), "bins": np.arange(3)}
    store.write_partial(4, "cafe0123cafe0123", good)
    with pytest.raises(RuntimeError, match="simulated writer crash"):
        store.write_partial(4, "cafe0123cafe0123",
                            {"version": _Exploding()})
    assert [f for f in os.listdir(store.root) if f.endswith(".tmp")] == []
    kept = store.read_partial(4, "cafe0123cafe0123")   # old payload intact
    np.testing.assert_array_equal(kept["bins"], good["bins"])


def test_fresh_partial_write_crash_leaves_nothing(tmp_path):
    store = TraceStore(str(tmp_path))
    with pytest.raises(RuntimeError):
        store.write_partial(0, "cafe0123cafe0123", {"x": _Exploding()})
    assert [f for f in os.listdir(store.root) if f.endswith(".tmp")] == []
    assert store.read_partial(0, "cafe0123cafe0123") is None
    assert not store.has_partial(0, "cafe0123cafe0123")


def test_corrupt_pack_footer_is_miss_not_crash(growing_trace, tmp_path):
    """A torn/corrupt partial-pack footer makes every entry of that
    shard a MISS (clean rescan), never a crash — and the rescan's write
    rewrites the pack clean."""
    ds, paths, cutoff = growing_trace
    store = _base_store(ds, paths, cutoff, str(tmp_path / "s"))
    first = run_aggregation(store, metrics=METRICS)
    qkey = store.partial_key((first.plan.t_start, first.plan.t_end,
                              first.plan.n_shards), METRICS, None)
    assert store.has_partial(0, qkey)
    path = os.path.join(store.root, pack_filename(0))
    with open(path, "wb") as f:
        f.write(b"not a pack file at all")
    store.clear_summaries()      # shards unchanged: only partials probed
    again = run_aggregation(TraceStore(store.root), metrics=METRICS)
    assert 0 in again.recomputed_shards          # recomputed, no crash
    np.testing.assert_array_equal(first.stats.count, again.stats.count)
    assert TraceStore(store.root).has_partial(0, qkey)   # self-healed


# --- garbage collection -----------------------------------------------------

def test_gc_drops_stale_summaries_and_partials_at_manifest_write(
        growing_trace, tmp_path):
    ds, paths, cutoff = growing_trace
    store = _base_store(ds, paths, cutoff, str(tmp_path / "s"))
    run_aggregation(store, metrics=METRICS)
    assert len(store.summary_keys()) == 1
    n_partials = len(store.partial_names())
    assert n_partials > 0

    # out-of-band rewrite (no invalidation hooks): both cache levels stale
    cols = store.read_shard(1)
    path = os.path.join(store.root, "shard_000001.npz")
    np.savez(path, **{k: v for k, v in cols.items()})
    man = store.read_manifest()
    store.write_manifest(man)                    # GC sweep runs here
    assert store.summary_keys() == []            # covered mismatch -> gone
    assert len(store.partial_names(1)) == 0      # fingerprint mismatch
    assert len(store.partial_names()) == n_partials - 1
