"""Trace diff & regression engine tests: fuzzy matcher properties
(variant spellings pair with their base kernel, unrelated names never
cross-match, symmetric, stable under enumeration order), sketch-shift
math, and the end-to-end verdict — an injected 1.5x slowdown on one
kernel family is ranked top of the DiffReport and flips the verdict,
with io_counts proving one fused scan per cold store and zero reads
when both summaries are warm."""

import os
import random
import shutil

import numpy as np
import pytest

from repro.core import (DiffThresholds, PipelineConfig, SyntheticSpec,
                        TraceStore, VariabilityPipeline, diff_cache_key,
                        diff_from_spec, diff_query, diff_spec,
                        generate_synthetic, inject_slowdown,
                        kernel_name_tokens, match_kernel_names,
                        normalize_kernel_name, run_generation,
                        sketch_shift, synthetic_kernel_names,
                        write_synthetic_dbs, Query)
from repro.core.reducers import SUBDIV, N_BUCKETS

# one kernel family (ids congruent mod 21) across three spelling styles:
# Itanium-mangled, Triton-suffixed, plain SASS-style
SLOW_IDS = (3, 24, 45)
SLOW_FAMILY = "layer_norm"


@pytest.fixture(scope="module")
def stores(tmp_path_factory):
    """Three stores over the SAME workload (one seed): baseline
    (name variant 0), a clean rebuild (variant 1 — respecialized
    spellings, identical data), and the rebuild with a 1.5x slowdown
    injected into one kernel family."""
    root = tmp_path_factory.mktemp("diff_base")
    common = dict(n_ranks=2, kernels_per_rank=4000, memcpys_per_rank=400,
                  duration_s=20.0, n_anomaly_windows=2, seed=7)
    ds_a = generate_synthetic(SyntheticSpec(**common, name_variant=0))
    ds_b = generate_synthetic(SyntheticSpec(**common, name_variant=1))
    ds_c = inject_slowdown(ds_b, 1.5, SLOW_IDS)
    out = {}
    for tag, ds in (("a", ds_a), ("b", ds_b), ("c", ds_c)):
        dbs = write_synthetic_dbs(ds, str(root / f"dbs_{tag}"))
        store = str(root / f"store_{tag}")
        run_generation(dbs, store, n_ranks=2)
        out[tag] = store
    return out


@pytest.fixture
def fresh_stores(stores, tmp_path):
    """Cache-cold copies for io-provenance tests."""
    out = {}
    for tag, src in stores.items():
        dst = str(tmp_path / tag)
        shutil.copytree(src, dst)
        ts = TraceStore(dst)
        ts.clear_summaries()
        ts.clear_partials()
        out[tag] = dst
    return out


def _pipe(backend="serial"):
    return VariabilityPipeline(PipelineConfig(n_ranks=2, backend=backend))


# --- fuzzy matcher properties (satellite: property tests) -------------------

def test_normalize_strips_specialization_noise():
    assert normalize_kernel_name(
        "_Z11gemm_kernelILi128ELi4EfEvPfPKfS1_i") == "gemm_kernel"
    assert normalize_kernel_name(
        "_ZN7cutlass6KernelI4GemmEEvNT_6ParamsE") == "cutlass::kernel"
    assert normalize_kernel_name(
        "triton_softmax_kernel_0d1d2d3de4de_9f86d081") == \
        "triton_softmax_kernel"
    assert normalize_kernel_name(
        "void rms_norm_kernel<float, 256>(float*, float const*, int)") == \
        "rms_norm_kernel"
    # a plain name is already canonical (modulo case)
    assert normalize_kernel_name("sm80_xmma_gemm_f16f16_f32_128x128_nn") \
        == "sm80_xmma_gemm_f16f16_f32_128x128_nn"


def test_variant_spellings_match_their_base_kernel():
    """Every id's variant-0 spelling pairs with the SAME id's variant-1
    spelling — mangled/Triton/template respecializations all resolve."""
    v0 = synthetic_kernel_names(64, variant=0)
    v1 = synthetic_kernel_names(64, variant=1)
    res = match_kernel_names(list(v0.values()), list(v1.values()))
    assert not res.unmatched_a and not res.unmatched_b
    pair = {m.name_a: m.name_b for m in res.matches}
    assert pair == {v0[i]: v1[i] for i in range(64)}
    vias = {m.via for m in res.matches}
    assert "exact" in vias          # plain style is variant-invariant
    assert "normalized" in vias     # respecialized styles


def test_unrelated_names_never_cross_match():
    a = ["_Z11gemm_kernelILi128EEvPf",
         "triton_softmax_kernel_0d1d2d3de4de_11aabb22",
         "sm80_xmma_reduce_sum_f16f16_f32_128x128_nn"]
    b = ["_Z16layer_norm_kernelILi256EEvPf",
         "triton_rope_embedding_kernel_0d1d2d3de4de_33ccdd44",
         "void adamw_step_kernel<float, 512>(float*)"]
    res = match_kernel_names(a, b)
    assert res.matches == []
    assert res.unmatched_a == sorted(a)
    assert res.unmatched_b == sorted(b)


def test_matching_is_symmetric_and_order_stable():
    v0 = list(synthetic_kernel_names(64, variant=0).values())
    v1 = list(synthetic_kernel_names(64, variant=1).values())
    fwd = match_kernel_names(v0, v1)
    rev = match_kernel_names(v1, v0)
    assert {(m.name_a, m.name_b) for m in fwd.matches} == \
        {(m.name_b, m.name_a) for m in rev.matches}
    # enumeration order of the inputs must not matter
    rng = random.Random(13)
    for _ in range(3):
        sa, sb = list(v0), list(v1)
        rng.shuffle(sa)
        rng.shuffle(sb)
        shuffled = match_kernel_names(sa, sb)
        assert shuffled == fwd


def test_token_fallback_requires_real_overlap():
    # same informative tokens, different decoration -> matches
    res = match_kernel_names(["fused_attention_rope_fwd_v2"],
                             ["fused_rope_attention_fwd"])
    assert len(res.matches) == 1 and res.matches[0].via == "tokens"
    # one shared generic token is not enough
    res = match_kernel_names(["flash_attention_fwd_kernel"],
                             ["flash_decode_split_kernel"])
    assert res.matches == []
    assert kernel_name_tokens("void kernel<int>(int*)") == frozenset()


# --- sketch shift math ------------------------------------------------------

def test_sketch_shift_recovers_bucket_translation():
    rng = np.random.default_rng(0)
    counts = np.zeros(N_BUCKETS)
    idx = rng.integers(40, 200, size=500)
    np.add.at(counts, idx, 1.0)
    for k in (4, 12):               # k buckets = k / SUBDIV octaves
        shifted = np.zeros(N_BUCKETS)
        np.add.at(shifted, idx + k, 1.0)
        signed, spread = sketch_shift(counts, shifted)
        assert signed == pytest.approx(k / SUBDIV, abs=1e-9)
        assert spread == pytest.approx(k / SUBDIV, abs=1e-9)
        back, _ = sketch_shift(shifted, counts)
        assert back == pytest.approx(-k / SUBDIV, abs=1e-9)
    # no evidence -> no shift
    assert sketch_shift(counts, np.zeros(N_BUCKETS)) == (0.0, 0.0)


def test_diff_spec_roundtrip_and_key():
    qa = Query(metrics=("k_stall",), ranks=(0, 1))
    qb = Query(metrics=("k_stall",))
    assert diff_from_spec(diff_spec(qa, qb)) == (qa, qb)
    with pytest.raises(ValueError):
        diff_from_spec({"a": qa.to_spec(), "bogus": 1})
    # ordered pair: diff(A,B) and diff(B,A) are different questions
    assert diff_cache_key(qa, qb) != diff_cache_key(qb, qa)
    # derived diff queries of equivalent bases share an identity
    assert diff_cache_key(diff_query(qa), diff_query(qb)) == \
        diff_cache_key(diff_query(dataclasses_replace_ranks(qa)),
                       diff_query(qb))


def dataclasses_replace_ranks(q):
    import dataclasses
    return dataclasses.replace(q, ranks=(1, 0))


# --- end-to-end verdicts ----------------------------------------------------

def test_self_diff_and_clean_rebuild_pass(stores):
    pipe = _pipe()
    rep = pipe.diff(stores["a"], stores["a"])
    assert rep.verdict == "pass" and not rep.regressions()
    # same workload, respecialized kernel spellings: all 64 groups align
    # across variants and nothing shifts (the data is identical)
    rep = pipe.diff(stores["a"], stores["b"])
    assert rep.verdict == "pass"
    assert len(rep.groups) == 64
    assert not rep.unmatched_a and not rep.unmatched_b
    assert all(abs(g.shift_octaves) < 1e-12 for g in rep.groups)
    assert all(g.mean_ratio == pytest.approx(1.0) for g in rep.groups)


def test_injected_slowdown_ranked_top_and_flips_verdict(stores):
    rep = _pipe().diff(stores["a"], stores["c"])
    assert rep.verdict == "regressed"
    top = rep.groups[:len(SLOW_IDS)]
    assert all(SLOW_FAMILY in normalize_kernel_name(g.name_a)
               for g in top)
    assert {g.name_a for g in rep.regressions()} == {g.name_a for g in top}
    for g in top:
        # geometric ratio recovers the injected 1.5x within sketch
        # quantization (1/8 octave buckets ~= 9% relative)
        assert g.geo_ratio == pytest.approx(1.5, rel=0.12)
        assert g.mean_ratio == pytest.approx(1.5, rel=0.05)
        assert g.top_bins and g.top_windows.shape == (len(g.top_bins), 2)
    # thresholds are configurable: an absurdly high bar passes the diff
    lax = _pipe().diff(stores["a"], stores["c"],
                       thresholds=DiffThresholds(mean_ratio=10.0,
                                                 p99_ratio=10.0,
                                                 shift_octaves=5.0))
    assert lax.verdict == "pass"


def _drop_diff_cache(store: str) -> None:
    for name in os.listdir(store):
        if name.startswith("diff_") and name.endswith(".json"):
            os.remove(os.path.join(store, name))


def test_diff_is_fused_and_warm_diff_reads_zero_shards(fresh_stores):
    """The three cost tiers, each labeled by its own provenance: cold =
    one fused scan per store, summary-warm = zero shard reads, repeat =
    the persisted diff report loads without running any query."""
    pipe = _pipe()
    n_shards = TraceStore(fresh_stores["a"]).read_manifest().n_shards
    cold = pipe.diff(fresh_stores["a"], fresh_stores["c"])
    # exactly ONE scan of each store's shard files, no re-reads
    assert not cold.from_cache
    assert cold.shard_reads_a == n_shards
    assert cold.shard_reads_b == n_shards
    # summary-warm (diff-result cache dropped): verdict off the cached
    # sketches alone
    _drop_diff_cache(fresh_stores["c"])
    warm = pipe.diff(fresh_stores["a"], fresh_stores["c"])
    assert not warm.from_cache
    assert warm.shard_reads_a == 0 and warm.shard_reads_b == 0
    # repeat: the report warm persisted is still valid — pure load
    cached = pipe.diff(fresh_stores["a"], fresh_stores["c"])
    assert cached.from_cache
    assert "diff-result cache hit" in cached.provenance()
    # deterministic: the machine verdict is identical across all tiers
    ra, rw, rc = cold.to_record(), warm.to_record(), cached.to_record()
    for r in (ra, rw, rc):
        r.pop("seconds")
        r.pop("shard_reads_a")
        r.pop("shard_reads_b")
        r.pop("diff_cached")
    assert ra == rw == rc
    # full fidelity through the cache: per-group shift arrays intact
    for gw, gc in zip(warm.groups, cached.groups):
        np.testing.assert_array_equal(gw.bin_shift, gc.bin_shift)
        np.testing.assert_array_equal(gw.top_windows, gc.top_windows)


def test_diff_cache_invalidated_by_store_change(fresh_stores):
    """A shard rewrite on either store must miss the diff-result cache;
    so must different thresholds (same stores)."""
    pipe = _pipe()
    first = pipe.diff(fresh_stores["a"], fresh_stores["c"])
    assert not first.from_cache
    assert pipe.diff(fresh_stores["a"], fresh_stores["c"]).from_cache
    # different thresholds: same key (filename), different fingerprint
    lax = pipe.diff(fresh_stores["a"], fresh_stores["c"],
                    thresholds=DiffThresholds(mean_ratio=10.0,
                                              p99_ratio=10.0,
                                              shift_octaves=5.0))
    assert not lax.from_cache
    # rewrite one shard of store A in place: fingerprint moves, cache
    # misses, the recomputed report matches the first bit-for-bit
    ts = TraceStore(fresh_stores["a"])
    ts.write_shard(0, ts.read_shard(0))
    again = pipe.diff(fresh_stores["a"], fresh_stores["c"])
    assert not again.from_cache
    assert again.verdict == first.verdict
    assert [g.name_a for g in again.groups] == \
        [g.name_a for g in first.groups]


def test_process_backend_diff_matches_serial(stores):
    _drop_diff_cache(stores["c"])      # force a real serial compute
    serial = _pipe("serial").diff(stores["a"], stores["c"])
    assert not serial.from_cache
    _drop_diff_cache(stores["c"])      # and a real process compute
    proc = _pipe("process").diff(stores["a"], stores["c"])
    assert not proc.from_cache
    assert proc.verdict == serial.verdict
    assert [g.name_a for g in proc.groups] == \
        [g.name_a for g in serial.groups]
    np.testing.assert_array_equal(
        [g.shift_octaves for g in proc.groups],
        [g.shift_octaves for g in serial.groups])


def test_record_shape_is_check_bench_consumable(stores):
    rec = _pipe().diff(stores["a"], stores["c"]).to_record(smoke=True)
    assert rec["kind"] == "diff" and rec["smoke"] is True
    assert rec["verdict"] in ("pass", "regressed")
    assert rec["matched_groups"] == 64
    assert len(rec["top"]) == 5
    assert rec["top"][0]["regressed"]
    shifts = [t["shift_octaves"] for t in rec["top"]]
    assert shifts == sorted(shifts, reverse=True)
