"""Hypothesis properties of the paper's shard partitioner (DESIGN.md §7)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property-test module; skips without it
from hypothesis import given, settings, strategies as st

from repro.core.sharding import (ShardPlan, assignment, block_assignment,
                                 contiguous_rank_range, cyclic_assignment,
                                 owner_of_shards)

TS = st.integers(min_value=0, max_value=2**40)


@settings(max_examples=50, deadline=None)
@given(t0=TS, width=st.integers(1, 2**40), n=st.integers(1, 500))
def test_boundaries_are_disjoint_cover(t0, width, n):
    plan = ShardPlan(t0, t0 + width, n)
    edges = plan.boundaries()
    assert edges[0] == t0 and edges[-1] == t0 + width
    assert np.all(np.diff(edges) >= 0)
    assert len(edges) == n + 1


@settings(max_examples=50, deadline=None)
@given(t0=TS, width=st.integers(100, 2**40), n=st.integers(1, 200),
       data=st.data())
def test_shard_of_maps_into_owning_shard(t0, width, n, data):
    plan = ShardPlan(t0, t0 + width, n)
    ts = data.draw(st.lists(
        st.integers(t0, t0 + width - 1), min_size=1, max_size=50))
    sid = plan.shard_of(np.asarray(ts, np.int64))
    assert np.all((sid >= 0) & (sid < n))
    edges = plan.boundaries()
    # binning agrees with boundary membership up to float rounding at the
    # shard rim (off-by-one max; binning itself is self-consistent)
    true_s = np.clip(np.searchsorted(edges, np.asarray(ts), "right") - 1,
                     0, n - 1)
    assert np.all(np.abs(true_s - sid) <= 1)


@settings(max_examples=100, deadline=None)
@given(n_shards=st.integers(0, 300), n_ranks=st.integers(1, 64),
       kind=st.sampled_from(["block", "cyclic"]))
def test_assignment_is_balanced_partition(n_shards, n_ranks, kind):
    sets = assignment(n_shards, n_ranks, kind)
    assert len(sets) == n_ranks
    sizes = [len(s) for s in sets]
    assert max(sizes) - min(sizes) <= 1          # balance (|nᵢ−n̄|≤1)
    allids = np.concatenate([s for s in sets]) if n_shards else \
        np.zeros(0, np.int64)
    assert len(allids) == n_shards
    assert len(np.unique(allids)) == n_shards    # disjoint cover


@settings(max_examples=50, deadline=None)
@given(n_shards=st.integers(1, 300), n_ranks=st.integers(1, 64))
def test_block_assignment_is_contiguous(n_shards, n_ranks):
    for ids in block_assignment(n_shards, n_ranks):
        if len(ids) > 1:
            assert np.all(np.diff(ids) == 1)


@settings(max_examples=50, deadline=None)
@given(n_shards=st.integers(1, 300), n_ranks=st.integers(1, 64))
def test_cyclic_assignment_stride(n_shards, n_ranks):
    for r, ids in enumerate(cyclic_assignment(n_shards, n_ranks)):
        if len(ids):
            assert ids[0] == r
            if len(ids) > 1:
                assert np.all(np.diff(ids) == n_ranks)


def test_owner_of_shards_consistent():
    owner = owner_of_shards(10, 3, "block")
    sets = assignment(10, 3, "block")
    for r, ids in enumerate(sets):
        assert np.all(owner[ids] == r)


def test_contiguous_rank_range_covers_block():
    plan = ShardPlan(0, 1000, 10)
    sets = block_assignment(10, 3)
    lo, hi = contiguous_rank_range(plan, sets[1])
    e = plan.boundaries()
    assert lo == e[sets[1][0]] and hi == e[sets[1][-1] + 1]


def test_from_interval_covers_range():
    plan = ShardPlan.from_interval(100, 1100, 300)
    assert plan.t_start == 100 and plan.t_end >= 1100
    assert plan.n_shards == 4


def test_empty_range_rejected():
    with pytest.raises(ValueError):
        ShardPlan(5, 5, 1)
    with pytest.raises(ValueError):
        ShardPlan(0, 10, 0)
