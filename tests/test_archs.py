"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward/train step on CPU with shape + finiteness
asserts, plus prefill→decode consistency for decoder archs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCH_NAMES, SHAPES, all_cells, applicable,
                           get_config, get_smoke_config, input_specs)
from repro.models.model import (decode_step, init_cache, init_params,
                                loss_fn, prefill)

RNG = np.random.default_rng(0)
B, S = 2, 24


def _batch_for(cfg):
    if cfg.frontend == "audio":
        return {"frames": jnp.asarray(
                    RNG.normal(size=(B, S, cfg.frontend_dim)), jnp.float32),
                "labels": jnp.asarray(
                    RNG.integers(0, cfg.vocab, (B, S)), jnp.int32),
                "loss_mask": jnp.ones((B, S), jnp.float32)}
    if cfg.frontend == "vlm":
        p = 8
        return {"patches": jnp.asarray(
                    RNG.normal(size=(B, p, cfg.frontend_dim)), jnp.float32),
                "tokens": jnp.asarray(
                    RNG.integers(0, cfg.vocab, (B, S - p)), jnp.int32),
                "positions3": jnp.broadcast_to(
                    jnp.arange(S + cfg.meta_tokens)[None, None],
                    (B, 3, S + cfg.meta_tokens)).astype(jnp.int32),
                "labels": jnp.asarray(
                    RNG.integers(0, cfg.vocab, (B, S - p)), jnp.int32)}
    return {"tokens": jnp.asarray(
                RNG.integers(0, cfg.vocab, (B, S)), jnp.int32),
            "labels": jnp.asarray(
                RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)

    def lf(p):
        return loss_fn(cfg, p, batch)[0]
    loss, grads = jax.value_and_grad(lf)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0.0


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if a != "hubert-xlarge"])
def test_smoke_prefill_decode_consistency(arch):
    """decode(prefix S-1) produces the same next-token logits as the full
    prefill's last position."""
    cfg = get_smoke_config(arch)
    if cfg.frontend == "vlm":
        pytest.skip("vlm decode covered via engine test")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)
    lg_full, _, _ = prefill(cfg, params, {"tokens": toks}, max_len=64,
                            cache_dtype=jnp.float32)
    lg_pre, caches, idx = prefill(cfg, params, {"tokens": toks[:, :-1]},
                                  max_len=64, cache_dtype=jnp.float32)
    lg_dec, _ = decode_step(cfg, params, toks[:, -1:], caches, idx)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_instantiates_symbolically(arch):
    """FULL configs are exercised as ShapeDtypeStructs only (no alloc)."""
    cfg = get_config(arch)
    sds = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(sds))
    expected = {                      # public figures, ±15%
        "hymba-1.5b": 1.5e9, "nemotron-4-15b": 15e9, "stablelm-3b": 2.8e9,
        "h2o-danube-1.8b": 1.8e9, "starcoder2-15b": 15e9,
        "hubert-xlarge": 1.0e9, "mamba2-370m": 0.37e9,
        "deepseek-v2-236b": 236e9, "granite-moe-1b-a400m": 1.3e9,
        "qwen2-vl-7b": 7.6e9,
    }[arch]
    assert 0.75 * expected < n < 1.35 * expected, \
        f"{arch}: {n/1e9:.2f}B params vs expected {expected/1e9:.2f}B"


def test_cell_applicability_matrix():
    """The assignment's 40 cells: 32 applicable, 8 structural skips."""
    cells = list(all_cells())
    assert len(cells) == 40
    ok = [c for c in cells if c[2]]
    skip = [c for c in cells if not c[2]]
    assert len(ok) == 32 and len(skip) == 8
    skip_set = {(a, s) for a, s, _, _ in skip}
    assert ("hubert-xlarge", "decode_32k") in skip_set
    assert ("hubert-xlarge", "long_500k") in skip_set
    for arch in ("nemotron-4-15b", "stablelm-3b", "starcoder2-15b",
                 "deepseek-v2-236b", "granite-moe-1b-a400m",
                 "qwen2-vl-7b"):
        assert (arch, "long_500k") in skip_set
    # sub-quadratic archs DO run long_500k
    for arch, s, ok_, _ in cells:
        if arch in ("hymba-1.5b", "mamba2-370m", "h2o-danube-1.8b") \
                and s == "long_500k":
            assert ok_


def test_input_specs_shapes():
    cfg = get_config("nemotron-4-15b")
    sp = input_specs(cfg, "train_4k")
    assert sp["tokens"].shape == (256, 4096)
    dec = input_specs(cfg, "decode_32k")
    assert dec["token"].shape == (128, 1)
    # decode cache covers the full 32k context
    leaves = jax.tree.leaves(dec["caches"])
    assert any(x.shape[2] >= 32768 for x in leaves if x.ndim >= 3)
