"""The multi-metric × group-by engine's correctness core:

  * one pass over M metrics is BIT-IDENTICAL, per metric, to M independent
    single-metric passes (same np.add.at order per (bin, group) cell);
  * the grouped tensor, merged over groups, equals the ungrouped statistic;
  * serial / process backends agree exactly and the jax collective backend
    agrees to float32 tolerance, on the same grouped tensor.
"""

import os

import numpy as np
import pytest

from repro.core import (GenerationConfig, PipelineConfig,
                        VariabilityPipeline, run_generation)
from repro.core.aggregation import (BinStats, bin_samples,
                                    bin_samples_grouped, run_aggregation)
from repro.core.anomaly import anomalous_bins, top_variability_bins
from repro.core.sharding import ShardPlan
from repro.core.tracestore import TraceStore

METRICS = ["k_stall", "m_duration"]


@pytest.fixture(scope="module")
def store_dir(small_dataset, tmp_path_factory):
    ds, paths = small_dataset
    out = str(tmp_path_factory.mktemp("mm_store"))
    run_generation(paths, out, n_ranks=2)
    return out


def test_grouped_binning_matches_manual_groupby():
    rng = np.random.default_rng(0)
    plan = ShardPlan(0, 10_000, 17)
    ts = rng.integers(0, 10_000, 800)
    vals = rng.normal(50, 20, (800, 2))
    gid = rng.integers(0, 3, 800)
    t = bin_samples_grouped(ts, vals, gid, 3, plan)
    assert t.count.shape == (17, 3, 2)
    for g in range(3):
        for j in range(2):
            ref = bin_samples(ts[gid == g], vals[gid == g, j], plan)
            np.testing.assert_array_equal(t.count[:, g, j], ref.count)
            np.testing.assert_array_equal(t.sum[:, g, j], ref.sum)
            np.testing.assert_array_equal(t.sumsq[:, g, j], ref.sumsq)
            np.testing.assert_array_equal(t.min[:, g, j], ref.min)
            np.testing.assert_array_equal(t.max[:, g, j], ref.max)


def test_group_merge_equals_ungrouped():
    rng = np.random.default_rng(1)
    plan = ShardPlan(0, 5_000, 11)
    ts = rng.integers(0, 5_000, 500)
    vals = rng.normal(10, 4, (500, 1))
    gid = rng.integers(0, 4, 500)
    t = bin_samples_grouped(ts, vals, gid, 4, plan).merge_groups()
    ref = bin_samples(ts, vals[:, 0], plan)
    np.testing.assert_array_equal(t.count[:, 0], ref.count)
    np.testing.assert_allclose(t.sum[:, 0], ref.sum, rtol=1e-12)
    np.testing.assert_array_equal(t.min[:, 0], ref.min)
    np.testing.assert_array_equal(t.max[:, 0], ref.max)


def test_multimetric_run_bit_identical_to_single_runs(store_dir):
    """The PR's acceptance criterion, on the sequential driver."""
    multi = run_aggregation(store_dir, metrics=METRICS, group_by="m_kind",
                            use_cache=False)
    assert multi.grouped.count.shape[2] == len(METRICS)
    for j, m in enumerate(METRICS):
        single = run_aggregation(store_dir, metrics=[m], group_by="m_kind",
                                 use_cache=False)
        np.testing.assert_array_equal(multi.group_keys, single.group_keys)
        for f in ("count", "sum", "sumsq", "min", "max"):
            np.testing.assert_array_equal(
                getattr(multi.grouped, f)[:, :, j],
                getattr(single.grouped, f)[:, :, 0])


def test_legacy_single_metric_api_unchanged(store_dir):
    """Positional legacy call still yields 1-D stats equal to the direct
    per-shard accumulation (bit-for-bit)."""
    res = run_aggregation(store_dir, use_cache=False)
    store = TraceStore(store_dir)
    plan = res.plan
    ref = BinStats.zeros(plan.n_shards)
    for s in store.shard_indices():
        cols = store.read_shard(s)
        ref = ref.merge(bin_samples(cols["k_start"].astype(np.int64),
                                    cols["k_stall"], plan))
    assert res.stats.count.ndim == 1
    np.testing.assert_array_equal(res.stats.count, ref.count)
    np.testing.assert_array_equal(res.stats.sum, ref.sum)
    np.testing.assert_array_equal(res.stats.min, ref.min)


def test_empty_shards_contribute_no_group_keys(store_dir):
    """Regression: an empty shard must not inject a phantom 0.0 group key
    (which would also desync serial/process group_keys from the jax
    backend's np.unique-over-data keys under the same cache key)."""
    store = TraceStore(store_dir)
    empty_idx = max(store.shard_indices()) + 1
    cols = store.read_shard(store.shard_indices()[0])
    store.write_shard(empty_idx, {k: v[:0] for k, v in cols.items()})
    try:
        # m_kind values are copyKind codes {-1, 1, 2, 8} — 0.0 is never a
        # real key, so a phantom empty-shard group is unambiguous.
        res = run_aggregation(store_dir, metrics=["k_stall"],
                              group_by="m_kind", use_cache=False)
        data_keys = set()
        for s in store.shard_indices():
            c = store.read_shard(s)
            if len(c["m_kind"]):
                data_keys.update(np.unique(c["m_kind"]).tolist())
        assert 0.0 not in data_keys
        np.testing.assert_array_equal(res.group_keys,
                                      np.asarray(sorted(data_keys)))
    finally:
        os.remove(os.path.join(store_dir, f"shard_{empty_idx:06d}.npz"))


def test_rank_count_invariance_grouped(store_dir):
    a = run_aggregation(store_dir, n_ranks=1, metrics=METRICS,
                        group_by="k_device", use_cache=False)
    b = run_aggregation(store_dir, n_ranks=4, metrics=METRICS,
                        group_by="k_device", use_cache=False)
    for f in ("count", "sum", "sumsq", "min", "max"):
        np.testing.assert_array_equal(getattr(a.grouped, f),
                                      getattr(b.grouped, f))


def test_result_select_and_anomaly_on_tensor(store_dir):
    res = run_aggregation(store_dir, metrics=METRICS, group_by="m_kind",
                          use_cache=False)
    sel = res.select(metric="k_stall")
    assert sel.count.ndim == 1
    np.testing.assert_array_equal(sel.count, res.stats.count)
    one = res.select(metric="m_duration", group=float(res.group_keys[0]))
    assert one.count.ndim == 1
    with pytest.raises(KeyError):
        res.select(metric=0, group=-1234.5)
    # detectors accept the tensor directly
    rep = anomalous_bins(res.grouped, boundaries=res.plan.boundaries())
    assert rep.scores.ndim == 1
    idx = top_variability_bins(res.grouped)
    assert idx.ndim == 1


def _run_backend(paths, workdir, backend, tag="mm", **kw):
    cfg = PipelineConfig(
        n_ranks=2, backend=backend, metrics=METRICS, group_by="k_device",
        use_summary_cache=False,
        generation=GenerationConfig(), **kw)
    return VariabilityPipeline(cfg).run(
        paths, os.path.join(workdir, f"{tag}_{backend}"))


def test_backends_agree_on_multimetric_tensor(small_dataset, tmp_path):
    """Satellite criterion: serial == process exactly; jax (float32
    collectives) to tolerance — on the full grouped moment tensor."""
    ds, paths = small_dataset
    a = _run_backend(paths, str(tmp_path), "serial")
    b = _run_backend(paths, str(tmp_path), "process")
    c = _run_backend(paths, str(tmp_path), "jax")
    ga, gb, gc = (r.aggregation.grouped for r in (a, b, c))
    for f in ("count", "sum", "sumsq", "min", "max"):
        np.testing.assert_array_equal(getattr(ga, f), getattr(gb, f))
    np.testing.assert_array_equal(a.aggregation.group_keys,
                                  c.aggregation.group_keys)
    np.testing.assert_allclose(gc.count, ga.count, rtol=1e-5)
    occ = ga.count > 0
    np.testing.assert_allclose(gc.mean[occ], ga.mean[occ],
                               rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(np.where(occ, gc.min, 0.0),
                               np.where(occ, ga.min, 0.0),
                               rtol=1e-5, atol=1e-2)
    np.testing.assert_array_equal(a.anomalies.top_idx, b.anomalies.top_idx)


def test_quantile_scores_end_to_end_all_backends(small_dataset, tmp_path):
    """The PR's acceptance criterion: ``anomalous_bins(..., score="p99")``
    and ``score="iqr"`` work end-to-end on serial/process/jax, with the
    process-backend quantile sketch BIT-IDENTICAL to serial and the jax
    path within sketch error bounds."""
    from repro.core.reducers import QUANTILE_REL_ERR

    ds, paths = small_dataset
    kw = dict(reducers=("moments", "quantile"), anomaly_score="p99")
    a = _run_backend(paths, str(tmp_path), "serial", tag="q", **kw)
    b = _run_backend(paths, str(tmp_path), "process", tag="q", **kw)
    c = _run_backend(paths, str(tmp_path), "jax", tag="q", **kw)

    sa, sb, sc = (r.aggregation.reduced["quantile"] for r in (a, b, c))
    np.testing.assert_array_equal(sa.counts, sb.counts)   # bit-identical
    assert sa.counts.sum() == sc.counts.sum()             # counts conserved

    # jax bucketization is float32; quantile answers must stay within one
    # bucket step of the serial float64 sketch (≲ 2*QUANTILE_REL_ERR).
    occ = a.aggregation.stats.count > 0
    for q in (0.5, 0.95, 0.99):
        pa = a.aggregation.sketch(metric=0).quantile(q)[occ]
        pc = c.aggregation.sketch(metric=0).quantile(q)[occ]
        np.testing.assert_allclose(pc, pa,
                                   rtol=2.5 * QUANTILE_REL_ERR)

    # identical host sketches => identical anomaly selection
    np.testing.assert_array_equal(a.anomalies.top_idx, b.anomalies.top_idx)
    assert a.anomalies.scores.shape == (a.aggregation.plan.n_shards,)

    # iqr fencing end-to-end on the serial backend + detector reuse on the
    # already-aggregated results of the other two
    i = _run_backend(paths, str(tmp_path), "serial", tag="qi",
                     reducers=("moments", "quantile"), anomaly_score="iqr")
    assert i.anomalies.scores.shape == (i.aggregation.plan.n_shards,)
    rep_b = anomalous_bins(b.aggregation, score="iqr")
    rep_c = anomalous_bins(c.aggregation, score="iqr")
    assert rep_b.scores.shape == rep_c.scores.shape


def test_quantile_suite_summary_cache_round_trip(small_dataset, tmp_path):
    ds, paths = small_dataset
    cfg = PipelineConfig(n_ranks=2, backend="serial", metrics=METRICS,
                         group_by="m_kind",
                         reducers=("moments", "quantile"),
                         anomaly_score="p95")
    pipe = VariabilityPipeline(cfg)
    res = pipe.run(paths, str(tmp_path / "store"))
    assert not res.aggregation.from_cache
    again = pipe.aggregate(str(tmp_path / "store"))
    assert again.from_cache
    np.testing.assert_array_equal(
        res.aggregation.reduced["quantile"].counts,
        again.reduced["quantile"].counts)
    # the cached sketch answers the same fences
    rep = anomalous_bins(again, score="p95")
    np.testing.assert_array_equal(res.anomalies.top_idx, rep.top_idx)


def test_jax_cache_entries_never_served_to_exact_backends(small_dataset,
                                                          tmp_path):
    """Regression: jax summaries derive from float32 collectives and are
    keyed precision='float32' — a later serial aggregation over the same
    store/query must recompute exactly, not read the jax entry."""
    ds, paths = small_dataset
    work = str(tmp_path / "store")
    jax_cfg = PipelineConfig(n_ranks=2, backend="jax", metrics=METRICS,
                             group_by="m_kind")
    VariabilityPipeline(jax_cfg).run(paths, work)
    ser_cfg = PipelineConfig(n_ranks=2, backend="serial", metrics=METRICS,
                             group_by="m_kind")
    warm_jax = VariabilityPipeline(jax_cfg).aggregate(work)
    assert warm_jax.from_cache                  # jax reuses its own entry
    serial = VariabilityPipeline(ser_cfg).aggregate(work)
    assert not serial.from_cache                # but serial recomputes
    cold = run_aggregation(work, metrics=METRICS, group_by="m_kind",
                           use_cache=False)
    for f in ("count", "sum", "sumsq", "min", "max"):
        np.testing.assert_array_equal(getattr(serial.grouped, f),
                                      getattr(cold.grouped, f))


def test_pipeline_summary_cache_round_trip(small_dataset, tmp_path):
    ds, paths = small_dataset
    cfg = PipelineConfig(n_ranks=2, backend="serial", metrics=METRICS,
                         group_by="m_kind")
    pipe = VariabilityPipeline(cfg)
    res = pipe.run(paths, str(tmp_path / "store"))
    assert not res.aggregation.from_cache
    again = pipe.aggregate(str(tmp_path / "store"))
    assert again.from_cache
    for f in ("count", "sum", "sumsq", "min", "max"):
        np.testing.assert_array_equal(getattr(res.aggregation.grouped, f),
                                      getattr(again.grouped, f))
