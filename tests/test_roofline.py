"""Roofline machinery: walker exactness on scans (the cost_analysis gap),
collective parsing, wire factors, model-flops bookkeeping."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import cost_analysis_dict
from repro.roofline import (PEAK_FLOPS, Roofline, active_param_count,
                            model_flops_for, parse_collectives)
from repro.roofline.hlo_cost import analyze_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_cost_analysis_undercounts_scans_and_walker_fixes_it():
    """Documents the XLA behaviour the walker exists for.

    ``cost_analysis()`` returns a list on jax<0.5 and a dict after;
    ``repro.compat.cost_analysis_dict`` absorbs the drift."""
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 256), jnp.float32)

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y
    c = _compile(f, x, w)
    expected = 2 * 8 * 256 * 256 * 12
    ca = cost_analysis_dict(c).get("flops", 0)
    assert ca < expected / 2                  # the gap
    walked = analyze_hlo(c.as_text(), 1)
    np.testing.assert_allclose(walked.flops, expected, rtol=1e-6)


def test_walker_nested_scan_multiplies():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 128), jnp.float32)

    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y
    c = _compile(f, x, w)
    walked = analyze_hlo(c.as_text(), 1)
    np.testing.assert_allclose(walked.flops, 2 * 4 * 128 * 128 * 15,
                               rtol=1e-6)


def test_walker_counts_unrolled_exactly():
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)

    def f(x, w):
        for _ in range(7):
            x = x @ w
        return x
    walked = analyze_hlo(_compile(f, x, w).as_text(), 1)
    np.testing.assert_allclose(walked.flops, 2 * 4 * 64 * 64 * 7,
                               rtol=1e-6)


def test_collective_parse_and_wire_factors(tmp_path):
    import subprocess, sys, textwrap, os
    code = textwrap.dedent("""
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import jax, jax.numpy as jnp, sys
        sys.path.insert(0, %r)
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh
        from repro.roofline import parse_collectives
        mesh = make_mesh((2,4), ('data','model'))
        x = jax.ShapeDtypeStruct((64, 512), jnp.float32)
        w1 = jax.ShapeDtypeStruct((512, 1024), jnp.float32)
        w2 = jax.ShapeDtypeStruct((1024, 512), jnp.float32)
        s = lambda *p: NamedSharding(mesh, P(*p))
        f = jax.jit(lambda a,b,c: jax.nn.relu(a@b)@c,
                    in_shardings=(s('data',None), s(None,'model'),
                                  s('model',None)),
                    out_shardings=s('data',None))
        comp = f.lower(x,w1,w2).compile()
        st = parse_collectives(comp.as_text(), 8)
        assert st.count.get('all-reduce', 0) >= 1, st.count
        assert st.result_bytes['all-reduce'] == 65536, st.result_bytes
        assert abs(st.wire_bytes - 65536*2*3/4) < 1, st.wire_bytes
        print('OK')
    """) % (os.path.join(os.path.dirname(__file__), "..", "src"),)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=300)
    assert "OK" in out.stdout, out.stderr[-2000:]


def test_roofline_terms_and_dominance():
    r = Roofline(arch="a", shape="s", mesh="m", chips=256,
                 flops_per_dev=197e12, bytes_per_dev=819e9 * 2,
                 wire_bytes_per_dev=50e9 * 0.5,
                 model_flops=197e12 * 256 * 0.5, collectives={})
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 2.0) < 1e-9
    assert abs(r.collective_s - 0.5) < 1e-9
    assert r.dominant == "memory"
    assert abs(r.step_s - 2.0) < 1e-9
    assert abs(r.useful_ratio - 0.5) < 1e-9


def test_model_flops_conventions():
    assert model_flops_for("train", 100, 10) == 6000
    assert model_flops_for("prefill", 100, 10) == 2000
    assert model_flops_for("decode", 100, 10) == 2000


def test_active_params_moe_scaling():
    import jax
    tree = {"segments": {"0": {
        "moe": {"experts": {"w_up": jax.ShapeDtypeStruct((8, 4, 4),
                                                         jnp.float32)}},
        "attn": {"wq": jax.ShapeDtypeStruct((4, 4, 4), jnp.float32)}}}}
    total, act = active_param_count(tree, top_k=2, n_experts=8)
    assert total == 8 * 16 + 64
    assert act == 2 * 16 + 64
