import os
import sys

# Tests see the real (single-CPU) device topology; ONLY the dry-run scripts
# force 512 host devices. Keep CPU parallelism modest for CI-like stability.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.core.events import SyntheticSpec, generate_synthetic, \
    write_synthetic_dbs


@pytest.fixture(scope="session")
def small_dataset(tmp_path_factory):
    """Session-scoped synthetic trace: 2 ranks, injected anomalies."""
    spec = SyntheticSpec(n_ranks=2, kernels_per_rank=4000,
                         memcpys_per_rank=600, duration_s=40.0,
                         n_anomaly_windows=2, seed=7)
    ds = generate_synthetic(spec)
    out = tmp_path_factory.mktemp("dbs")
    paths = write_synthetic_dbs(ds, str(out))
    return ds, paths
